"""TargetPlatform: one homogeneous cluster + its FaaS platform (paper §3).

Reproduces the FaaS semantics the paper measures against:
  * replicas with cold / prewarm / warm lifecycle (OpenWhisk §6.1),
  * reactive autoscaling + faas-idler scale-to-zero (OpenFaaS §2.2.2),
  * GCF elastic unbounded instances w/ per-instance concurrency 1 (§2.2.3),
  * CPU / memory interference from background load (§5.1.2, Figs. 8-9),
  * queueing when capacity is exhausted,
  * per-platform energy accounting (§5.2).

Execution latency comes from an ExecutionModel that can either (a) use the
analytic cost (flops / replica_flops + data-access time from the placement
manager) or (b) really execute the function's JAX callable on the host CPU
once, cache the measurement, and scale it by the platform/host speed ratio.
Everything advances on the deterministic SimClock.
"""
from __future__ import annotations

import time as wall_time
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional

from repro.core.data_placement import DataPlacementManager
from repro.core.energy import EnergyMeter
from repro.core.monitoring import MetricsRegistry
from repro.core.simulator import SimClock
from repro.core.types import FunctionSpec, Invocation, PlatformProfile

COLD, PREWARM, WARM = "cold", "prewarm", "warm"


class Replica:
    __slots__ = ("state", "busy", "last_used", "fn", "retired")

    def __init__(self, fn: str, state: str = COLD):
        self.fn = fn
        self.state = state
        self.busy = False
        self.last_used = 0.0
        # set when the idler / destroy / recover removes the replica; lets
        # the free-list skip stale entries lazily instead of rebuilding
        self.retired = False


class ExecutionModel:
    """Latency model with optional real-measurement calibration."""

    def __init__(self, host_flops: float = 2e9):
        self.host_flops = host_flops
        self._measured: Dict[str, float] = {}

    def measure_real(self, fn: FunctionSpec, payloads) -> Optional[float]:
        if fn.real_fn is None:
            return None
        if fn.name not in self._measured:
            try:
                fn.real_fn(*payloads)              # warmup/compile
                t0 = wall_time.perf_counter()
                fn.real_fn(*payloads)
                self._measured[fn.name] = wall_time.perf_counter() - t0
            except Exception:
                self._measured[fn.name] = -1.0
        m = self._measured[fn.name]
        return None if m < 0 else m

    def exec_seconds(self, fn: FunctionSpec, prof: PlatformProfile,
                     payloads=()) -> float:
        real = self.measure_real(fn, payloads)
        if real is not None:
            # scale host measurement by platform-vs-host speed ratio
            return real * (self.host_flops / max(prof.replica_flops, 1.0))
        return fn.flops / max(prof.replica_flops, 1.0)


class TargetPlatform:
    def __init__(self, prof: PlatformProfile, clock: SimClock,
                 metrics: MetricsRegistry, energy: EnergyMeter,
                 placement: Optional[DataPlacementManager] = None,
                 exec_model: Optional[ExecutionModel] = None,
                 seed: int = 0):
        self.prof = prof
        self.clock = clock
        self.metrics = metrics
        self.energy = energy
        self.placement = placement
        self.exec_model = exec_model or ExecutionModel()
        self.replicas: Dict[str, List[Replica]] = defaultdict(list)
        # O(1) admission accounting: busy-replica counter + per-function
        # free-replica pools keyed by lifecycle state.  The old full scans
        # of every replica per admission went quadratic under sustained
        # batch load (elastic platforms grow replicas without bound).
        self._busy = 0
        self._free: Dict[str, Dict[str, List[Replica]]] = {}
        self.queue: deque = deque()
        self.deployed: Dict[str, FunctionSpec] = {}
        self.failed = False
        self.bg_cpu = 0.0                  # §5.1.2 interference knobs
        self.bg_mem = 0.0
        self.on_complete: List[Callable[[Invocation], None]] = []
        self.on_fail: List[Callable[[Invocation], None]] = []
        self.inflight: Dict[int, Invocation] = {}
        energy.register(prof, clock.now())
        self._idler_scheduled = False

    # ------------------------------------------------------------ deploy --
    def deploy(self, fn: FunctionSpec):
        """Function Deployer: registers fn; ARM platforms need ARM images."""
        if self.prof.arm and fn.runtime == "docker-x86":
            raise ValueError(f"{fn.name}: x86 image cannot run on ARM "
                             f"platform {self.prof.name}")
        self.deployed[fn.name] = fn
        for _ in range(self.prof.prewarm_pool):
            rep = Replica(fn.name, PREWARM)
            self.replicas[fn.name].append(rep)
            self._push_free(rep)

    def destroy(self, fn_name: str):
        self.deployed.pop(fn_name, None)
        for r in self.replicas.pop(fn_name, []):
            if r.busy and not r.retired:
                self._busy -= 1
            r.retired = True
        self._free.pop(fn_name, None)

    # ------------------------------------------------------- accounting ---
    def busy_replicas(self) -> int:
        return self._busy

    def _push_free(self, rep: Replica):
        pools = self._free.get(rep.fn)
        if pools is None:
            pools = {WARM: [], PREWARM: [], COLD: []}
            self._free[rep.fn] = pools
        pools[rep.state].append(rep)

    def replica_count(self, fn: str) -> int:
        return len(self.replicas[fn])

    def cpu_util(self) -> float:
        cap = max(self.prof.total_replicas, 1)
        return min(1.0, self.bg_cpu + self.busy_replicas() / cap)

    def mem_used_mb(self) -> float:
        used = sum(len(rs) * self.deployed[f].memory_mb
                   for f, rs in self.replicas.items() if f in self.deployed)
        return used + self.bg_mem * self.prof.total_memory_mb

    def mem_util(self) -> float:
        return min(1.5, self.mem_used_mb() / max(self.prof.total_memory_mb,
                                                 1))

    def _touch_energy(self):
        self.energy.update(self.prof.name, self.clock.now(), self.cpu_util())

    def _sample_infra(self):
        if not self.prof.infra_metrics_visible:
            return
        t = self.clock.now()
        self.metrics.add(self.prof.name, "_infra", "cpu_util", t,
                         self.cpu_util())
        self.metrics.add(self.prof.name, "_infra", "mem_util", t,
                         self.mem_util())

    # ------------------------------------------------------- scheduling ---
    def can_start_replica(self, fn: FunctionSpec) -> bool:
        if self.prof.elastic:
            return True
        # Background CPU load does NOT reserve replica slots (the OS time-
        # shares; slowdown is modeled in _interference_factor — Fig. 8).
        if self.busy_replicas() >= self.prof.total_replicas:
            return False
        free_mb = self.prof.total_memory_mb - self.mem_used_mb()
        if free_mb >= fn.memory_mb:
            return True
        # CPU platforms can overcommit into swap (Fig. 9's cliff applies);
        # TPU pods (chips > 0) cannot — HBM does not swap.
        return self.prof.chips == 0 and \
            fn.memory_mb <= self.prof.total_memory_mb

    def invoke(self, inv: Invocation):
        """Entry point from the sidecar/control plane."""
        if not self._enqueue(inv):
            return
        self._drain()
        self._schedule_idler()

    def invoke_batch(self, invs):
        """Batched entry point: enqueue the whole group, then drain once.

        FIFO semantics are identical to repeated ``invoke`` calls (the
        drain loop assigns replicas in queue order either way); the saving
        is one queue drain + one energy/infra sample per batch instead of
        per invocation."""
        queued = False
        for inv in invs:
            queued = self._enqueue(inv) or queued
        if queued:
            self._drain()
            self._schedule_idler()

    def _enqueue(self, inv: Invocation) -> bool:
        if self.failed:
            self._fail(inv, "platform down")
            return False
        if inv.fn.name not in self.deployed:
            self._fail(inv, "function not deployed")
            return False
        inv.platform = self.prof.name
        inv.scheduled_t = self.clock.now()
        inv.status = "queued"
        self.inflight[inv.id] = inv
        self.queue.append(inv)
        return True

    def _find_replica(self, fn: str) -> Optional[Replica]:
        """Warmest free replica (WARM > PREWARM > COLD), popped from the
        per-state free pools in O(1); stale entries (retired by the idler,
        or whose state moved on) are skipped lazily."""
        pools = self._free.get(fn)
        if pools is None:
            return None
        for state in (WARM, PREWARM, COLD):
            lst = pools[state]
            while lst:
                r = lst.pop()
                if r.retired or r.busy or r.state != state:
                    continue
                return r
        return None

    def _drain(self):
        progressed = True
        while progressed and self.queue and not self.failed:
            progressed = False
            inv = self.queue[0]
            # the invocation's own spec governs execution (chain stages
            # carry per-instance data_objects); deployment was checked at
            # enqueue, and for plain invocations both are the same object
            fn = inv.fn
            rep = self._find_replica(fn.name)
            if rep is None and self.can_start_replica(fn):
                rep = Replica(fn.name, COLD)
                self.replicas[fn.name].append(rep)
            if rep is None:
                break
            self.queue.popleft()
            self._start(inv, fn, rep)
            progressed = True
        self._touch_energy()
        self._sample_infra()

    # -------------------------------------------------------- execution ---
    def _interference_factor(self) -> float:
        """CPU + memory interference (paper §5.1.2, Figs. 8-9).

        CPU: background load occupies bg_cpu * cores fully; while function
        replicas fit on the remaining free cores there is no slowdown
        (paper: +50%% load -> no effect). Once they spill onto bg-occupied
        cores the OS time-shares 1:1 -> ~2x (paper: +100%% load -> ~2x P90).

        Memory: swap thrash is a cliff — as soon as demand exceeds physical
        memory, latency jumps ~7x (paper: 0.8 s -> 6 s P90).
        """
        total = max(self.prof.total_replicas, 1)
        free_cores = (1.0 - self.bg_cpu) * total
        busy = self.busy_replicas()
        factor = 1.0 if busy <= free_cores + 1e-9 else 2.0
        pressure = self.mem_util()
        if pressure > 1.0 + 1e-6:                   # swap cliff (Fig. 9)
            factor *= 7.0
        return factor

    def _start(self, inv: Invocation, fn: FunctionSpec, rep: Replica):
        now = self.clock.now()
        startup = 0.0
        if rep.state == COLD:
            startup = self.prof.cold_start_s
            inv.cold_start = True
        elif rep.state == PREWARM:
            startup = self.prof.cold_start_s * 0.15
            inv.cold_start = True
        rep.state = WARM
        rep.busy = True
        rep.last_used = now
        self._busy += 1

        data_t = 0.0
        payloads = []
        if self.placement is not None:
            for obj in fn.data_objects:
                data_t += self.placement.access_time(obj, self.prof.name)
                self.placement.record_access(fn.name, obj)
                payloads.append(self.placement.payload(obj))
        exec_t = self.exec_model.exec_seconds(fn, self.prof, payloads)
        # interference slows the whole request path (gateway/watchdog/
        # invoker contend for the same cores and memory as the function)
        exec_t = (exec_t + self.prof.overhead_s) * \
            self._interference_factor()

        inv.status = "running"
        inv.start_t = now + startup
        inv.queue_time = inv.start_t - inv.arrival_t
        inv.exec_time = exec_t + data_t
        inv.data_time = data_t
        self._touch_energy()

        def finish():
            rep.busy = False
            rep.last_used = self.clock.now()
            if not rep.retired:
                self._busy -= 1
                self._push_free(rep)
            if self.failed or inv.status == "failed":
                return
            inv.end_t = self.clock.now()
            inv.status = "done"
            self.inflight.pop(inv.id, None)
            self.metrics.record_completion(
                inv, visible_infra=self.prof.infra_metrics_visible)
            self.metrics.add(self.prof.name, fn.name, "replicas",
                             inv.end_t, float(self.replica_count(fn.name)))
            for cb in self.on_complete:
                cb(inv)
            self._drain()

        self.clock.after(startup + inv.exec_time, finish)

    def _fail(self, inv: Invocation, reason: str):
        inv.status = "failed"
        inv.end_t = self.clock.now()
        self.inflight.pop(inv.id, None)
        for cb in self.on_fail:
            cb(inv)

    # ------------------------------------------------ faas-idler / warm ---
    def _schedule_idler(self):
        if self._idler_scheduled or self.prof.scale_to_zero_s <= 0:
            return
        self._idler_scheduled = True

        def idle_check():
            self._idler_scheduled = False
            now = self.clock.now()
            for fn, rs in list(self.replicas.items()):
                keep = []
                for r in rs:
                    if r.busy or now - r.last_used < \
                            self.prof.scale_to_zero_s or r.state == PREWARM:
                        keep.append(r)
                    else:
                        r.retired = True
                self.replicas[fn] = keep
            self._touch_energy()
            if any(self.replicas.values()):
                self._schedule_idler()

        self.clock.after(self.prof.scale_to_zero_s, idle_check)

    def prewarm(self, fn_name: str, n: int):
        """Predictive prewarming from the EventModel forecast (§3.3 (1))."""
        for _ in range(n):
            rep = Replica(fn_name, PREWARM)
            self.replicas[fn_name].append(rep)
            self._push_free(rep)

    # ------------------------------------------------------------ faults --
    def fail(self):
        """Platform outage: every in-flight invocation is lost."""
        self.failed = True
        lost = list(self.inflight.values())
        self.inflight.clear()
        self.queue.clear()
        for inv in lost:
            self._fail(inv, "platform failure")
        self._touch_energy()

    def recover(self):
        self.failed = False
        for rs in self.replicas.values():
            for r in rs:
                r.retired = True
            rs.clear()
        self._free.clear()
        self._busy = 0
