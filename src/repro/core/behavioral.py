"""Behavioral Modeling (paper §3.3): online-learned models that drive
runtime decisions.

  * ``P2Quantile``        — streaming P90 estimator (P² algorithm), the
                            user-centric SLO signal.
  * ``EWMA``              — exponentially-weighted scalar estimator.
  * ``EventModel``        — invocation-rate tracking + Holt linear forecast;
                            feeds predictive prewarming (cold-start
                            avoidance, §6.1).
  * ``FunctionPerformanceModel`` — per (function, platform) execution time /
                            energy model, updated online; the Scheduler's
                            main input (§3.1.3).
  * ``DataAccessModel``   — object access frequencies per function; feeds
                            data placement (§5.1.4).
  * ``InteractionModel``  — producer/consumer co-invocation graph (§6.3).

The performance model's estimator state is *columnar*: every (function,
platform) EWMA / P² estimator lives in preallocated NumPy arrays
(``PerfState``, grown by doubling), not in dicts of Python objects.  The
scalar ``observe`` path reads one cell into Python floats, runs exactly
the classic update, and writes the cell back — float64 round-trips are
bit-exact, so the columnar state produces byte-identical predictions to
the historical object state.  What the arrays buy is the vectorized
read side: ``predict_matrix`` builds a whole (F, P) prediction block in
one pass, and ``estimator_columns`` exports the raw state the fused
jitted admission step gathers from.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.core.types import FunctionSpec, Invocation, PlatformProfile, SLO


class P2Quantile:
    """Jain & Chlamtac's P² streaming quantile estimator."""

    def __init__(self, q: float = 0.9):
        self.q = q
        self._init: List[float] = []
        self.n: Optional[List[int]] = None
        self.ns: Optional[List[float]] = None
        self.heights: Optional[List[float]] = None
        self.count = 0

    def add(self, x: float):
        self.count += 1
        if self.heights is None:
            self._init.append(x)
            if len(self._init) == 5:
                self._init.sort()
                self.heights = list(self._init)
                self.n = [0, 1, 2, 3, 4]
                self.ns = [0, 2 * self.q, 4 * self.q,
                           2 + 2 * self.q, 4]
            return
        _p2_update(self.heights, self.n, self.ns, self.q, x)

    def value(self) -> float:
        if self.heights is None:
            if not self._init:
                return float("nan")
            s = sorted(self._init)
            return s[min(int(self.q * len(s)), len(s) - 1)]
        return self.heights[2]


def _p2_update(h: List[float], n: List[int], ns: List[float],
               q: float, x: float) -> None:
    """One post-bootstrap P² marker update, in place on plain Python
    lists/floats (the shared scalar core of ``P2Quantile`` and the
    columnar cells in ``PerfState`` — identical arithmetic, bit-exact)."""
    if x < h[0]:
        h[0] = x
        k = 0
    elif x >= h[4]:
        h[4] = x
        k = 3
    else:
        k = next(i for i in range(4) if h[i] <= x < h[i + 1])
    for i in range(k + 1, 5):
        n[i] += 1
    for i, d in enumerate((0, q / 2, q, (1 + q) / 2, 1)):
        ns[i] += d
    for i in (1, 2, 3):
        d = ns[i] - n[i]
        if (d >= 1 and n[i + 1] - n[i] > 1) or \
           (d <= -1 and n[i - 1] - n[i] < -1):
            d = 1 if d > 0 else -1
            # parabolic
            hp = h[i] + d / (n[i + 1] - n[i - 1]) * (
                (n[i] - n[i - 1] + d) * (h[i + 1] - h[i]) /
                (n[i + 1] - n[i]) +
                (n[i + 1] - n[i] - d) * (h[i] - h[i - 1]) /
                (n[i] - n[i - 1]))
            if not h[i - 1] < hp < h[i + 1]:
                hp = h[i] + d * (h[i + d] - h[i]) / (n[i + d] - n[i])
            h[i] = hp
            n[i] += d


class EWMA:
    def __init__(self, alpha: float = 0.2, init: Optional[float] = None):
        self.alpha = alpha
        self.v = init
        self.count = 0

    def add(self, x: float):
        self.count += 1
        self.v = x if self.v is None else \
            self.alpha * x + (1 - self.alpha) * self.v

    def value(self, default: float = float("nan")) -> float:
        return default if self.v is None else self.v


class EventModel:
    """Application Event Model: per-function arrival rate + Holt forecast."""

    def __init__(self, window_s: float = 10.0, alpha: float = 0.5,
                 beta: float = 0.3):
        self.window_s = window_s
        self.alpha, self.beta = alpha, beta
        self._counts: Dict[str, Dict[int, int]] = defaultdict(
            lambda: defaultdict(int))
        self._level: Dict[str, float] = {}
        self._trend: Dict[str, float] = {}
        self._last_w: Dict[str, int] = {}

    def record(self, fn: str, t: float):
        self.record_many(fn, t, 1)

    def record_many(self, fn: str, t: float, count: int = 1):
        """Fold ``count`` simultaneous arrivals (one batch) into the rate
        model — equivalent to ``count`` calls to ``record(fn, t)`` but one
        window update."""
        if count <= 0:
            return
        w = int(t // self.window_s)
        self._counts[fn][w] += count
        lw = self._last_w.get(fn)
        if lw is None:
            self._last_w[fn] = w
            return
        while lw < w:                      # close finished windows
            x = float(self._counts[fn][lw])
            lvl = self._level.get(fn, x)
            tr = self._trend.get(fn, 0.0)
            new_lvl = self.alpha * x + (1 - self.alpha) * (lvl + tr)
            self._trend[fn] = self.beta * (new_lvl - lvl) + \
                (1 - self.beta) * tr
            self._level[fn] = new_lvl
            lw += 1
        self._last_w[fn] = w

    def forecast_rate(self, fn: str, horizon_windows: int = 1) -> float:
        lvl = self._level.get(fn)
        if lvl is None:
            return 0.0
        return max(0.0, (lvl + horizon_windows * self._trend.get(fn, 0.0))
                   / self.window_s)


# ---------------------------------------------------------------------------
# Columnar estimator state
# ---------------------------------------------------------------------------

class QuantileState(NamedTuple):
    """Struct-of-arrays P² state for an (F, P) grid of estimators.

    ``buf`` holds the first five observations per cell (the bootstrap
    window); once a cell's count reaches 5 its ``heights`` / ``pos`` /
    ``want`` markers take over, exactly like ``P2Quantile``."""

    buf: np.ndarray       # (F, P, 5) f8  bootstrap observations
    heights: np.ndarray   # (F, P, 5) f8  marker heights
    pos: np.ndarray       # (F, P, 5) i8  marker positions (n)
    want: np.ndarray      # (F, P, 5) f8  desired positions (n')
    count: np.ndarray     # (F, P)    i8  observations seen

    @staticmethod
    def alloc(nf: int, npl: int) -> "QuantileState":
        return QuantileState(
            np.zeros((nf, npl, 5)), np.zeros((nf, npl, 5)),
            np.zeros((nf, npl, 5), np.int64), np.zeros((nf, npl, 5)),
            np.zeros((nf, npl), np.int64))

    def grown(self, nf: int, npl: int) -> "QuantileState":
        new = QuantileState.alloc(nf, npl)
        f, p = self.count.shape
        for dst, src in zip(new, self):
            dst[:f, :p] = src
        return new


class PerfState(NamedTuple):
    """Preallocated columnar estimator state of the performance model:
    exec-time EWMA, exec/response P² P90s per (function, platform) cell,
    cold-start EWMA per platform."""

    exec_v: np.ndarray    # (F, P) f8  exec EWMA value (NaN until first obs)
    exec_n: np.ndarray    # (F, P) i8  exec EWMA count
    exec_q: QuantileState                    # exec-time P90
    resp_q: QuantileState                    # response-time P90
    cold_v: np.ndarray    # (P,) f8   cold-start EWMA value
    cold_n: np.ndarray    # (P,) i8   cold-start EWMA count

    @staticmethod
    def alloc(nf: int, npl: int) -> "PerfState":
        return PerfState(
            np.full((nf, npl), np.nan), np.zeros((nf, npl), np.int64),
            QuantileState.alloc(nf, npl), QuantileState.alloc(nf, npl),
            np.full(npl, np.nan), np.zeros(npl, np.int64))

    def grown(self, nf: int, npl: int) -> "PerfState":
        new = PerfState.alloc(nf, npl)
        f, p = self.exec_n.shape
        new.exec_v[:f, :p] = self.exec_v
        new.exec_n[:f, :p] = self.exec_n
        new.cold_v[:p] = self.cold_v
        new.cold_n[:p] = self.cold_n
        return new._replace(exec_q=self.exec_q.grown(nf, npl),
                            resp_q=self.resp_q.grown(nf, npl))


def _q_add(qs: QuantileState, fi: int, pi: int, x: float, q: float) -> None:
    """Scalar P² add on one columnar cell — bit-exact ``P2Quantile.add``
    (cells round-trip through float64, which is lossless)."""
    c = int(qs.count[fi, pi])
    qs.count[fi, pi] = c + 1
    if c < 5:
        qs.buf[fi, pi, c] = x
        if c == 4:
            s = sorted(float(v) for v in qs.buf[fi, pi])
            qs.heights[fi, pi] = s
            qs.pos[fi, pi] = (0, 1, 2, 3, 4)
            qs.want[fi, pi] = (0, 2 * q, 4 * q, 2 + 2 * q, 4)
        return
    h = [float(v) for v in qs.heights[fi, pi]]
    n = [int(v) for v in qs.pos[fi, pi]]
    ns = [float(v) for v in qs.want[fi, pi]]
    _p2_update(h, n, ns, q, x)
    qs.heights[fi, pi] = h
    qs.pos[fi, pi] = n
    qs.want[fi, pi] = ns


def _q_value(qs: QuantileState, fi: int, pi: int, q: float) -> float:
    c = int(qs.count[fi, pi])
    if c == 0:
        return float("nan")
    if c < 5:
        s = sorted(float(v) for v in qs.buf[fi, pi, :c])
        return s[min(int(q * c), c - 1)]
    return float(qs.heights[fi, pi, 2])


class _QuantileCell:
    """Live read view of one (function, platform) P² cell — the dict-of-
    ``P2Quantile`` surface (``.count`` / ``.value()``) kept for external
    readers (hedging's observation gate)."""

    __slots__ = ("_model", "_attr", "_fi", "_pi", "q")

    def __init__(self, model: "FunctionPerformanceModel", attr: str,
                 fi: int, pi: int, q: float = 0.9):
        self._model = model
        self._attr = attr
        self._fi, self._pi = fi, pi
        self.q = q

    @property
    def count(self) -> int:
        qs = getattr(self._model._state, self._attr)
        return int(qs.count[self._fi, self._pi])

    def value(self) -> float:
        return _q_value(getattr(self._model._state, self._attr),
                        self._fi, self._pi, self.q)


class _EwmaCell:
    """Live read view of one exec-EWMA cell (``.count`` / ``.value()``)."""

    __slots__ = ("_model", "_fi", "_pi")

    def __init__(self, model: "FunctionPerformanceModel", fi: int, pi: int):
        self._model = model
        self._fi, self._pi = fi, pi

    @property
    def count(self) -> int:
        return int(self._model._state.exec_n[self._fi, self._pi])

    def value(self, default: float = float("nan")) -> float:
        if self.count == 0:
            return default
        return float(self._model._state.exec_v[self._fi, self._pi])


class _PairMap:
    """Read-only mapping facade over the (function, platform) estimator
    grid: ``get((fn_name, platform_name))`` returns a live cell view, or
    ``default`` when that pair has no observations (matching the lazy
    defaultdicts the columnar state replaced)."""

    __slots__ = ("_model", "_attr")

    def __init__(self, model: "FunctionPerformanceModel", attr: str):
        self._model = model
        self._attr = attr

    def _cell(self, key) -> Optional[object]:
        m = self._model
        fi = m._frow.get(key[0])
        pi = m._pcol.get(key[1])
        if fi is None or pi is None:
            return None
        if self._attr == "exec_ewma":
            if int(m._state.exec_n[fi, pi]) == 0:
                return None
            return _EwmaCell(m, fi, pi)
        attr = "exec_q" if self._attr == "exec_p90" else "resp_q"
        if int(getattr(m._state, attr).count[fi, pi]) == 0:
            return None
        return _QuantileCell(m, attr, fi, pi)

    def get(self, key, default=None):
        cell = self._cell(key)
        return default if cell is None else cell

    def __getitem__(self, key):
        cell = self._cell(key)
        if cell is None:
            raise KeyError(key)
        return cell

    def __contains__(self, key) -> bool:
        return self._cell(key) is not None


class FunctionPerformanceModel:
    """Per (function, platform): exec-time EWMA + P90 + cold-start EWMA,
    held in preallocated columnar arrays (``PerfState``).

    ``predict`` falls back to an analytic estimate from the platform profile
    when no observations exist yet (bootstrap from FDNInspector benchmarking
    results stored in the KnowledgeBase, when available).  The scalar
    ``predict_*`` calls and the vectorized ``predict_matrix`` are IEEE-
    identical element for element — policies may use either.
    """

    ALPHA = 0.2                      # exec/cold EWMA smoothing
    Q = 0.9                          # P² quantile

    def __init__(self):
        self._state = PerfState.alloc(32, 8)
        self._frow: Dict[str, int] = {}      # function name -> row
        self._pcol: Dict[str, int] = {}      # platform name -> column
        self.version = 0                     # bumped on every state write
        # single-slot gather memo: within one admission burst the fused
        # jit step (estimator_columns) and the decision journal
        # (predict_matrix) gather the same (fns, profs) block with no
        # state write in between — keyed by object identity + version,
        # the snapshot _fn_cache discipline
        self._gather_cache = None
        self._analytic_cache = None
        self._power_cache = None
        # dict-of-estimators read surface, now backed by the arrays
        self.exec_ewma = _PairMap(self, "exec_ewma")
        self.exec_p90 = _PairMap(self, "exec_p90")
        self.resp_p90 = _PairMap(self, "resp_p90")

    # ------------------------------------------------------ state access --
    def _cell(self, fn_name: str, platform_name: str) -> Tuple[int, int]:
        """Row/column of one (function, platform) pair, growing the
        preallocated arrays by doubling when a name is new."""
        fi = self._frow.get(fn_name)
        if fi is None:
            fi = self._frow[fn_name] = len(self._frow)
        pi = self._pcol.get(platform_name)
        if pi is None:
            pi = self._pcol[platform_name] = len(self._pcol)
        nf, npl = self._state.exec_n.shape
        if fi >= nf or pi >= npl:
            while fi >= nf:
                nf *= 2
            while pi >= npl:
                npl *= 2
            self._state = self._state.grown(nf, npl)
        return fi, pi

    def _ewma_cell_add(self, v: np.ndarray, n: np.ndarray, idx,
                       x: float) -> None:
        c = int(n[idx])
        if c == 0:
            v[idx] = x
        else:
            v[idx] = self.ALPHA * x + (1 - self.ALPHA) * float(v[idx])
        n[idx] = c + 1

    # --------------------------------------------------------- updates ----
    def observe(self, inv: Invocation):
        fi, pi = self._cell(inv.fn.name, inv.platform or "?")
        st = self._state
        self._ewma_cell_add(st.exec_v, st.exec_n, (fi, pi), inv.exec_time)
        _q_add(st.exec_q, fi, pi, inv.exec_time, self.Q)
        rt = inv.response_time
        if rt is not None:
            _q_add(st.resp_q, fi, pi, rt, self.Q)
        if inv.cold_start and inv.platform:
            self._ewma_cell_add(st.cold_v, st.cold_n, pi, inv.queue_time)
        self.version += 1

    def fold_observations(self, fn_name: str, platform_name: str,
                          exec_s: float, resp_s: float, k: int) -> None:
        """Fold ``k`` identical observations into one cell in O(1) — the
        streaming-replay update, where a whole minute chunk contributes
        one aggregate per (function, platform).

        The EWMA fold is the exact closed form for a constant input
        (``v' = x + (1-a)^k (v - x)``); the P² markers advance with up to
        8 repeats of the aggregate (a constant input converges the
        estimator to itself — further identical repeats only translate
        marker positions, not heights).  This path trades bit-parity for
        O(chunks) cost and is used *only* by the streaming replayer,
        never by the discrete-event simulator."""
        if k <= 0:
            return
        fi, pi = self._cell(fn_name, platform_name)
        st = self._state
        c = int(st.exec_n[fi, pi])
        if c == 0:
            st.exec_v[fi, pi] = exec_s
        else:
            w = (1 - self.ALPHA) ** k
            st.exec_v[fi, pi] = exec_s + w * \
                (float(st.exec_v[fi, pi]) - exec_s)
        st.exec_n[fi, pi] = c + k
        reps = min(k, 8)
        for _ in range(reps):
            _q_add(st.exec_q, fi, pi, exec_s, self.Q)
            _q_add(st.resp_q, fi, pi, resp_s, self.Q)
        # account the folded population in the bootstrap gates too
        st.exec_q.count[fi, pi] += k - reps
        st.resp_q.count[fi, pi] += k - reps
        self.version += 1

    # ------------------------------------------------------ cold starts ---
    def predict_cold(self, platform_name: str,
                     default: float = float("nan")) -> float:
        pi = self._pcol.get(platform_name)
        if pi is None or int(self._state.cold_n[pi]) == 0:
            return default
        return float(self._state.cold_v[pi])

    # ------------------------------------------------- scalar predicts ----
    def analytic_exec(self, fn: FunctionSpec,
                      prof: PlatformProfile) -> float:
        compute = fn.flops / max(prof.replica_flops, 1.0)
        data = (fn.read_bytes + fn.write_bytes) / max(prof.net_bw, 1.0)
        return compute + data

    def predict_exec(self, fn: FunctionSpec, prof: PlatformProfile) -> float:
        fi = self._frow.get(fn.name)
        pi = self._pcol.get(prof.name)
        if fi is not None and pi is not None and \
                int(self._state.exec_n[fi, pi]) >= 3:
            return float(self._state.exec_v[fi, pi])
        return self.analytic_exec(fn, prof)

    def predict_p90_response(self, fn: FunctionSpec,
                             prof: PlatformProfile) -> float:
        fi = self._frow.get(fn.name)
        pi = self._pcol.get(prof.name)
        if fi is not None and pi is not None and \
                int(self._state.resp_q.count[fi, pi]) >= 10:
            return _q_value(self._state.resp_q, fi, pi, self.Q)
        return self.predict_exec(fn, prof) * 1.5

    def predict_energy(self, fn: FunctionSpec,
                       prof: PlatformProfile) -> float:
        """Joules for one invocation, charging the WHOLE platform's loaded
        power for the execution duration — the paper's Table-4 accounting
        (the platform is powered for the workload; an 11x-faster machine
        that burns 17x the power still loses on energy)."""
        t = self.predict_exec(fn, prof)
        return t * prof.nodes * prof.loaded_w_per_node

    # --------------------------------------------- vectorized predicts ----
    def _gather(self, fns: Sequence[FunctionSpec],
                profs: Sequence[PlatformProfile]):
        """Raw (F, P) gathers of the estimator grid for the given function
        x platform block: exec EWMA value/count, response-P90 height/count
        (counts zeroed for never-observed pairs)."""
        key = (self.version, tuple(id(f) for f in fns),
               tuple(id(p) for p in profs))
        hit = self._gather_cache
        if hit is not None and hit[0] == key:
            return hit[1]
        st = self._state
        rows = np.array([self._frow.get(fn.name, -1) for fn in fns],
                        dtype=np.intp)
        cols = np.array([self._pcol.get(p.name, -1) for p in profs],
                        dtype=np.intp)
        valid = (rows >= 0)[:, None] & (cols >= 0)[None, :]
        ix = np.ix_(np.maximum(rows, 0), np.maximum(cols, 0))
        ev = np.where(valid, st.exec_v[ix], 0.0)
        en = np.where(valid, st.exec_n[ix], 0)
        rh = np.where(valid, st.resp_q.heights[:, :, 2][ix], 0.0)
        rc = np.where(valid, st.resp_q.count[ix], 0)
        # cells still in the 5-sample bootstrap have no marker heights;
        # their count (< 10) keeps them on the analytic branch anyway,
        # but scrub counts so the fused step can gate on rc >= 10 alone
        rc = np.where(rc >= 5, rc, 0)
        self._gather_cache = (key, (ev, en, rh, rc))
        return ev, en, rh, rc

    def analytic_matrix(self, fns: Sequence[FunctionSpec],
                        profs: Sequence[PlatformProfile]) -> np.ndarray:
        """(F, P) analytic exec seconds — elementwise IEEE-identical to
        ``analytic_exec`` (same operand order, float64 throughout)."""
        key = (tuple(id(f) for f in fns), tuple(id(p) for p in profs))
        hit = self._analytic_cache
        if hit is not None and hit[0] == key:
            return hit[1]
        flops = np.array([fn.flops for fn in fns])
        rw = np.array([fn.read_bytes + fn.write_bytes for fn in fns])
        rfl = np.array([max(p.replica_flops, 1.0) for p in profs])
        nbw = np.array([max(p.net_bw, 1.0) for p in profs])
        out = flops[:, None] / rfl[None, :] + rw[:, None] / nbw[None, :]
        self._analytic_cache = (key, out)
        return out

    def predict_matrix(self, fns: Sequence[FunctionSpec],
                       profs: Sequence[PlatformProfile],
                       p90: bool = False, energy: bool = False
                       ) -> Dict[str, np.ndarray]:
        """One vectorized pass over the estimator arrays building the
        (F, P) prediction block the snapshot's ``fn_matrix`` serves:
        ``exec_s`` (+ ``p90_s`` / ``energy_j`` on request).  Every element
        equals the corresponding scalar ``predict_*`` call bit for bit."""
        ev, en, rh, rc = self._gather(fns, profs)
        exec_s = np.where(en >= 3, ev, self.analytic_matrix(fns, profs))
        out = {"exec_s": exec_s}
        if p90:
            out["p90_s"] = np.where(rc >= 10, rh, exec_s * 1.5)
        if energy:
            pk = tuple(id(p) for p in profs)
            hit = self._power_cache
            if hit is not None and hit[0] == pk:
                nodes, lw = hit[1]
            else:
                nodes = np.array([float(p.nodes) for p in profs])
                lw = np.array([p.loaded_w_per_node for p in profs])
                self._power_cache = (pk, (nodes, lw))
            out["energy_j"] = (exec_s * nodes[None, :]) * lw[None, :]
        return out

    def estimator_columns(self, fns: Sequence[FunctionSpec],
                          profs: Sequence[PlatformProfile]
                          ) -> Dict[str, np.ndarray]:
        """Raw gathered state for the fused jitted admission step
        (``repro.kernels.policy_score.fused_composite_decide``): the
        device kernel applies the observation-count gates itself."""
        ev, en, rh, rc = self._gather(fns, profs)
        return {"ewma_v": ev, "ewma_n": en, "resp_h2": rh, "resp_n": rc,
                "analytic_s": self.analytic_matrix(fns, profs)}

    # ------------------------------------------------ deployment advice ---
    def recommend(self, fn: FunctionSpec,
                  profiles: Sequence[PlatformProfile],
                  kb=None) -> Dict[str, object]:
        """Per-function deployment advice (paper §3.6, absorbed from the
        retired Recommender): best platform for latency, for energy, and
        whether the two disagree — one ``predict_matrix`` pass instead of
        2 x P scalar predictions."""
        m = self.predict_matrix([fn], profiles, energy=True)
        lat = {p.name: float(m["exec_s"][0, j])
               for j, p in enumerate(profiles)}
        eng = {p.name: float(m["energy_j"][0, j])
               for j, p in enumerate(profiles)}
        feasible = [p for p in profiles
                    if p.total_memory_mb >= fn.memory_mb]
        if not feasible:
            return {"function": fn.name, "error": "fits nowhere"}
        best_lat = min(feasible, key=lambda p: lat[p.name]).name
        best_eng = min(feasible, key=lambda p: eng[p.name]).name
        return {
            "function": fn.name,
            "latency_best": best_lat,
            "energy_best": best_eng,
            "tradeoff": best_lat != best_eng,
            "historical": kb.best_platform(fn.name) if kb else None,
            "predicted_exec_s": {k: round(v, 4) for k, v in lat.items()},
            "predicted_energy_j": {k: round(v, 3) for k, v in eng.items()},
        }


class DataAccessModel:
    def __init__(self):
        self.reads: Dict[Tuple[str, str], int] = defaultdict(int)
        self.writes: Dict[Tuple[str, str], int] = defaultdict(int)

    def record_read(self, fn: str, obj: str, count: int = 1):
        self.reads[(fn, obj)] += count

    def record_write(self, fn: str, obj: str, count: int = 1):
        self.writes[(fn, obj)] += count

    def hot_objects(self, fn: str, k: int = 5) -> List[str]:
        items = [(o, c) for (f, o), c in self.reads.items() if f == fn]
        items.sort(key=lambda x: -x[1])
        return [o for o, _ in items[:k]]


class InteractionModel:
    """Producer->consumer edges between functions (composition, §6.3)."""

    def __init__(self, window_s: float = 1.0):
        self.window_s = window_s
        self.edges: Dict[Tuple[str, str], int] = defaultdict(int)
        self._last: Optional[Tuple[str, float]] = None

    def record(self, fn: str, t: float):
        if self._last is not None:
            lf, lt = self._last
            if t - lt <= self.window_s and lf != fn:
                self.edges[(lf, fn)] += 1
        self._last = (fn, t)

    def record_batch(self, fns: List[str], t: float):
        """Fold a simultaneous arrival burst (one batch admission) into
        the co-invocation graph — equivalent to ``record(fn, t)`` per
        invocation in stream order, but one pass: every adjacent pair of
        *distinct* functions inside the burst (dt = 0 <= window) adds one
        edge, plus the boundary pair against the previous arrival."""
        if not fns:
            return
        if self._last is not None:
            lf, lt = self._last
            if t - lt <= self.window_s and lf != fns[0]:
                self.edges[(lf, fns[0])] += 1
        for prev, cur in zip(fns, fns[1:]):
            if prev != cur:
                self.edges[(prev, cur)] += 1
        self._last = (fns[-1], t)

    def record_batch_columns(self, fn_idx: np.ndarray,
                             names: Sequence[str], t: float):
        """Columnar ``record_batch``: the burst arrives as an int column
        plus a decode table.  Edge *counts* match the sequential fold
        exactly; only the dict insertion order of brand-new edges may
        differ (np.unique visits pairs sorted, not in stream order)."""
        m = len(fn_idx)
        if m == 0:
            return
        first = names[int(fn_idx[0])]
        if self._last is not None:
            lf, lt = self._last
            if t - lt <= self.window_s and lf != first:
                self.edges[(lf, first)] += 1
        a, b = fn_idx[:-1], fn_idx[1:]
        keep = a != b
        if keep.any():
            # encode (i, j) pairs as one int64 key: a native sort inside
            # np.unique instead of the void-dtype axis=0 path, with the
            # same lexicographic visit order
            k = len(names)
            key = a[keep].astype(np.int64) * k + b[keep]
            uniq, counts = np.unique(key, return_counts=True)
            for q, c in zip(uniq.tolist(), counts.tolist()):
                self.edges[(names[q // k], names[q % k])] += int(c)
        self._last = (names[int(fn_idx[-1])], t)

    def compose_candidates(self, min_count: int = 10) -> List[Tuple[str,
                                                                    str]]:
        return [e for e, c in self.edges.items() if c >= min_count]


# ---------------------------------------------------------------------------
# Function composition (§6.3) — absorbed from the retired tuning module
# ---------------------------------------------------------------------------

def compose_functions(a: FunctionSpec, b: FunctionSpec,
                      transition_overhead_s: float = 0.0) -> FunctionSpec:
    """Compose a->b into one function (paper §6.3).

    The composed function's demands are the sums; intermediate-result I/O
    between members disappears (b's reads of a's writes become in-memory),
    and the platform charges one invocation instead of two — the paper's
    cost argument for composition.
    """
    internal = min(a.write_bytes, b.read_bytes)
    real_fn = None
    if a.real_fn is not None and b.real_fn is not None:
        def real_fn(*args, _a=a.real_fn, _b=b.real_fn):
            return _b(_a(*args))
    return FunctionSpec(
        name=f"{a.name}+{b.name}",
        flops=a.flops + b.flops,
        read_bytes=a.read_bytes + max(b.read_bytes - internal, 0.0),
        write_bytes=max(a.write_bytes - internal, 0.0) + b.write_bytes,
        memory_mb=max(a.memory_mb, b.memory_mb),
        runtime=a.runtime,
        data_objects=tuple(dict.fromkeys(a.data_objects + b.data_objects)),
        real_fn=real_fn,
        slo=SLO(min(a.slo.p90_response_s, b.slo.p90_response_s)),
    )


def composition_plan(im: InteractionModel, fns: Dict[str, FunctionSpec],
                     min_count: int = 10) -> List[FunctionSpec]:
    """Fold every hot producer->consumer edge into a composed function."""
    out = []
    for src, dst in im.compose_candidates(min_count):
        if src in fns and dst in fns:
            out.append(compose_functions(fns[src], fns[dst]))
    return out
