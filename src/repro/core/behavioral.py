"""Behavioral Modeling (paper §3.3): online-learned models that drive
runtime decisions.

  * ``P2Quantile``        — streaming P90 estimator (P² algorithm), the
                            user-centric SLO signal.
  * ``EWMA``              — exponentially-weighted scalar estimator.
  * ``EventModel``        — invocation-rate tracking + Holt linear forecast;
                            feeds predictive prewarming (cold-start
                            avoidance, §6.1).
  * ``FunctionPerformanceModel`` — per (function, platform) execution time /
                            energy model, updated online; the Scheduler's
                            main input (§3.1.3).
  * ``DataAccessModel``   — object access frequencies per function; feeds
                            data placement (§5.1.4).
  * ``InteractionModel``  — producer/consumer co-invocation graph (§6.3).
"""
from __future__ import annotations

import math
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from repro.core.types import FunctionSpec, Invocation, PlatformProfile


class P2Quantile:
    """Jain & Chlamtac's P² streaming quantile estimator."""

    def __init__(self, q: float = 0.9):
        self.q = q
        self._init: List[float] = []
        self.n: Optional[List[int]] = None
        self.ns: Optional[List[float]] = None
        self.heights: Optional[List[float]] = None
        self.count = 0

    def add(self, x: float):
        self.count += 1
        if self.heights is None:
            self._init.append(x)
            if len(self._init) == 5:
                self._init.sort()
                self.heights = list(self._init)
                self.n = [0, 1, 2, 3, 4]
                self.ns = [0, 2 * self.q, 4 * self.q,
                           2 + 2 * self.q, 4]
            return
        h, n, ns, q = self.heights, self.n, self.ns, self.q
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = next(i for i in range(4) if h[i] <= x < h[i + 1])
        for i in range(k + 1, 5):
            n[i] += 1
        for i, d in enumerate((0, q / 2, q, (1 + q) / 2, 1)):
            ns[i] += d
        for i in (1, 2, 3):
            d = ns[i] - n[i]
            if (d >= 1 and n[i + 1] - n[i] > 1) or \
               (d <= -1 and n[i - 1] - n[i] < -1):
                d = 1 if d > 0 else -1
                # parabolic
                hp = h[i] + d / (n[i + 1] - n[i - 1]) * (
                    (n[i] - n[i - 1] + d) * (h[i + 1] - h[i]) /
                    (n[i + 1] - n[i]) +
                    (n[i + 1] - n[i] - d) * (h[i] - h[i - 1]) /
                    (n[i] - n[i - 1]))
                if not h[i - 1] < hp < h[i + 1]:
                    hp = h[i] + d * (h[i + d] - h[i]) / (n[i + d] - n[i])
                h[i] = hp
                n[i] += d

    def value(self) -> float:
        if self.heights is None:
            if not self._init:
                return float("nan")
            s = sorted(self._init)
            return s[min(int(self.q * len(s)), len(s) - 1)]
        return self.heights[2]


class EWMA:
    def __init__(self, alpha: float = 0.2, init: Optional[float] = None):
        self.alpha = alpha
        self.v = init
        self.count = 0

    def add(self, x: float):
        self.count += 1
        self.v = x if self.v is None else \
            self.alpha * x + (1 - self.alpha) * self.v

    def value(self, default: float = float("nan")) -> float:
        return default if self.v is None else self.v


class EventModel:
    """Application Event Model: per-function arrival rate + Holt forecast."""

    def __init__(self, window_s: float = 10.0, alpha: float = 0.5,
                 beta: float = 0.3):
        self.window_s = window_s
        self.alpha, self.beta = alpha, beta
        self._counts: Dict[str, Dict[int, int]] = defaultdict(
            lambda: defaultdict(int))
        self._level: Dict[str, float] = {}
        self._trend: Dict[str, float] = {}
        self._last_w: Dict[str, int] = {}

    def record(self, fn: str, t: float):
        self.record_many(fn, t, 1)

    def record_many(self, fn: str, t: float, count: int = 1):
        """Fold ``count`` simultaneous arrivals (one batch) into the rate
        model — equivalent to ``count`` calls to ``record(fn, t)`` but one
        window update."""
        if count <= 0:
            return
        w = int(t // self.window_s)
        self._counts[fn][w] += count
        lw = self._last_w.get(fn)
        if lw is None:
            self._last_w[fn] = w
            return
        while lw < w:                      # close finished windows
            x = float(self._counts[fn][lw])
            lvl = self._level.get(fn, x)
            tr = self._trend.get(fn, 0.0)
            new_lvl = self.alpha * x + (1 - self.alpha) * (lvl + tr)
            self._trend[fn] = self.beta * (new_lvl - lvl) + \
                (1 - self.beta) * tr
            self._level[fn] = new_lvl
            lw += 1
        self._last_w[fn] = w

    def forecast_rate(self, fn: str, horizon_windows: int = 1) -> float:
        lvl = self._level.get(fn)
        if lvl is None:
            return 0.0
        return max(0.0, (lvl + horizon_windows * self._trend.get(fn, 0.0))
                   / self.window_s)


class FunctionPerformanceModel:
    """Per (function, platform): exec-time EWMA + P90 + cold-start EWMA.

    ``predict`` falls back to an analytic estimate from the platform profile
    when no observations exist yet (bootstrap from FDNInspector benchmarking
    results stored in the KnowledgeBase, when available).
    """

    def __init__(self):
        self.exec_ewma: Dict[Tuple[str, str], EWMA] = defaultdict(EWMA)
        self.exec_p90: Dict[Tuple[str, str], P2Quantile] = defaultdict(
            P2Quantile)
        self.resp_p90: Dict[Tuple[str, str], P2Quantile] = defaultdict(
            P2Quantile)
        self.cold_ewma: Dict[str, EWMA] = defaultdict(EWMA)

    def observe(self, inv: Invocation):
        key = (inv.fn.name, inv.platform or "?")
        self.exec_ewma[key].add(inv.exec_time)
        self.exec_p90[key].add(inv.exec_time)
        if inv.response_time is not None:
            self.resp_p90[key].add(inv.response_time)
        if inv.cold_start and inv.platform:
            self.cold_ewma[inv.platform].add(inv.queue_time)

    def analytic_exec(self, fn: FunctionSpec,
                      prof: PlatformProfile) -> float:
        compute = fn.flops / max(prof.replica_flops, 1.0)
        data = (fn.read_bytes + fn.write_bytes) / max(prof.net_bw, 1.0)
        return compute + data

    def predict_exec(self, fn: FunctionSpec, prof: PlatformProfile) -> float:
        key = (fn.name, prof.name)
        e = self.exec_ewma.get(key)
        if e is not None and e.count >= 3:
            return e.value()
        return self.analytic_exec(fn, prof)

    def predict_p90_response(self, fn: FunctionSpec,
                             prof: PlatformProfile) -> float:
        key = (fn.name, prof.name)
        p = self.resp_p90.get(key)
        if p is not None and p.count >= 10:
            return p.value()
        return self.predict_exec(fn, prof) * 1.5

    def predict_energy(self, fn: FunctionSpec,
                       prof: PlatformProfile) -> float:
        """Joules for one invocation, charging the WHOLE platform's loaded
        power for the execution duration — the paper's Table-4 accounting
        (the platform is powered for the workload; an 11x-faster machine
        that burns 17x the power still loses on energy)."""
        t = self.predict_exec(fn, prof)
        return t * prof.nodes * prof.loaded_w_per_node


class DataAccessModel:
    def __init__(self):
        self.reads: Dict[Tuple[str, str], int] = defaultdict(int)
        self.writes: Dict[Tuple[str, str], int] = defaultdict(int)

    def record_read(self, fn: str, obj: str, count: int = 1):
        self.reads[(fn, obj)] += count

    def record_write(self, fn: str, obj: str, count: int = 1):
        self.writes[(fn, obj)] += count

    def hot_objects(self, fn: str, k: int = 5) -> List[str]:
        items = [(o, c) for (f, o), c in self.reads.items() if f == fn]
        items.sort(key=lambda x: -x[1])
        return [o for o, _ in items[:k]]


class InteractionModel:
    """Producer->consumer edges between functions (composition, §6.3)."""

    def __init__(self, window_s: float = 1.0):
        self.window_s = window_s
        self.edges: Dict[Tuple[str, str], int] = defaultdict(int)
        self._last: Optional[Tuple[str, float]] = None

    def record(self, fn: str, t: float):
        if self._last is not None:
            lf, lt = self._last
            if t - lt <= self.window_s and lf != fn:
                self.edges[(lf, fn)] += 1
        self._last = (fn, t)

    def record_batch(self, fns: List[str], t: float):
        """Fold a simultaneous arrival burst (one batch admission) into
        the co-invocation graph — equivalent to ``record(fn, t)`` per
        invocation in stream order, but one pass: every adjacent pair of
        *distinct* functions inside the burst (dt = 0 <= window) adds one
        edge, plus the boundary pair against the previous arrival."""
        if not fns:
            return
        if self._last is not None:
            lf, lt = self._last
            if t - lt <= self.window_s and lf != fns[0]:
                self.edges[(lf, fns[0])] += 1
        for prev, cur in zip(fns, fns[1:]):
            if prev != cur:
                self.edges[(prev, cur)] += 1
        self._last = (fns[-1], t)

    def compose_candidates(self, min_count: int = 10) -> List[Tuple[str,
                                                                    str]]:
        return [e for e, c in self.edges.items() if c >= min_count]
