"""Reproduce the paper's headline energy result (Table 4) and show the FDN
making the energy-aware decision automatically.

    PYTHONPATH=src python examples/energy_aware_scheduling.py
"""
from repro.core import (EnergyAwarePolicy, FDNControlPlane, Gateway,
                        Invocation)
from repro.core import functions as fn_mod
from repro.core import profiles
from repro.core.loadgen import attach_completion_hooks, run_open_loop
from repro.core.types import DeploymentSpec


def run_exclusive(pname: str, rps=40.0, duration=300.0):
    cp = FDNControlPlane()
    cp.create_platform(profiles.PAPER_PLATFORMS[pname])
    fns = fn_mod.paper_functions()
    fn_mod.seed_object_stores(cp.placement, location=pname)
    cp.deploy(DeploymentSpec("t", list(fns.values()), [pname]))
    attach_completion_hooks(cp)
    res = run_open_loop(cp.clock,
                        lambda i: cp.submit(i, platform_override=pname),
                        fns["JSON-loads"], rps, duration)
    cp.run_until(cp.clock.now())
    return res, cp.energy.joules(pname)


def main():
    print("== Table 4: JSON-loads at fixed arrival rate, 300 s ==")
    joules = {}
    for pname in ("edge-cluster", "hpc-node-cluster"):
        res, j = run_exclusive(pname)
        joules[pname] = j
        print(f"{pname:>20s}: served={len(res.completed):6d} "
              f"p90={res.p90_response():6.3f}s  energy={j:9.1f} J")
    print(f"energy ratio: {joules['hpc-node-cluster'] / joules['edge-cluster']:.1f}x "
          f"(paper: 16.9x)")

    print("\n== the FDN makes this choice automatically ==")
    cp = FDNControlPlane()
    for pname in ("edge-cluster", "hpc-node-cluster"):
        cp.create_platform(profiles.PAPER_PLATFORMS[pname])
    fns = fn_mod.paper_functions()
    fn_mod.seed_object_stores(cp.placement, location="edge-cluster")
    cp.deploy(DeploymentSpec("t", list(fns.values()), list(cp.platforms)))
    attach_completion_hooks(cp)
    cp.policy = EnergyAwarePolicy(cp.perf)
    gw = Gateway(cp)
    choice = cp.policy.choose(Invocation(fns["JSON-loads"], 0.0),
                              cp.alive_platforms())
    print(f"EnergyAwarePolicy routes JSON-loads -> {choice.prof.name}")
    from repro.core.types import SLO
    strict_primes = fns["primes-python"].replace(slo=SLO(5.0))
    choice = cp.policy.choose(Invocation(strict_primes, 0.0),
                              cp.alive_platforms())
    print(f"EnergyAwarePolicy routes primes-python (5 s SLO) -> "
          f"{choice.prof.name} (edge would violate the SLO)")


if __name__ == "__main__":
    main()
