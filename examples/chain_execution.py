"""Function chains end to end: plan a chain with the data-gravity
planner, execute it collaboratively across platforms, inspect the A/B.

Walkthrough in three acts:

 1. Build an FDN over two platforms and plan the ``ab-dual-source``
    chain in every mode — watch the assignment change with the WAN
    bandwidth (co-location vs collaborative split, paper §3.1.3/§5.1.4).
 2. Execute one instance through the control plane and follow the
    intermediates through the object stores.
 3. Run the registered ``chains/split-vs-colocate-ab`` scenario and
    print the per-chain report section: the split arm wins end-to-end
    p90 on a fast interconnect, the co-located arm wins on a slow WAN.

    PYTHONPATH=src python examples/chain_execution.py
"""
from repro.chains import DataGravityPlanner, catalog
from repro.core import profiles as prof_mod
from repro.core.control_plane import FDNControlPlane
from repro.core.scheduler import PerformanceRankedPolicy
from repro.core.types import DeploymentSpec
from repro.inspector import run_scenario
from repro.inspector.registry import split_vs_colocate

PAIR = ("cloud-cluster", "old-hpc-node-cluster")


def build(bw: float):
    cp = FDNControlPlane()
    for name in PAIR:
        cp.create_platform(prof_mod.PAPER_PLATFORMS[name])
    cp.policy = PerformanceRankedPolicy(cp.perf)
    cp.placement.set_bandwidth(*PAIR, bw)
    tmpl = catalog.get("ab-dual-source")
    fns = dict(tmpl.functions)
    cp.deploy(DeploymentSpec("chains", list(fns.values()), list(PAIR)))
    for inp in tmpl.inputs:
        cp.placement.stores[inp.location].put(inp.key, inp.size_bytes)
    return cp, fns, tmpl


def act1_planning():
    print("== 1. planning: the same chain under two interconnects ==")
    for bw, tag in ((2e9, "fast 2 GB/s"), (3e6, "slow 3 MB/s")):
        cp, fns, tmpl = build(bw)
        planner = DataGravityPlanner(cp.policy, cp.placement, fns)
        plats = [cp.platforms[n] for n in PAIR]
        for mode in ("colocate", "split", "auto"):
            plan = planner.plan(tmpl.chain, plats, mode=mode)
            short = {s: p.split("-")[0] for s, p in plan.assignment.items()}
            print(f"  {tag:12s} {mode:9s} -> {plan.mode:9s} {short} "
                  f"est_makespan={plan.est_makespan_s:.2f}s "
                  f"est_transfer={plan.est_transfer_s:.2f}s")


def act2_execution():
    print("\n== 2. one instance through the control plane ==")
    cp, fns, tmpl = build(2e9)
    planner = DataGravityPlanner(cp.policy, cp.placement, fns)
    ex = cp.chain_executor(fns)
    plan = planner.plan(tmpl.chain,
                        [cp.platforms[n] for n in PAIR], mode="auto")
    inst = ex.launch(tmpl.chain, plan, label="demo")
    cp.clock.run_until(600.0)
    print(f"  status={inst.status} latency={inst.latency:.3f}s "
          f"stages={inst.stages_done}/{tmpl.chain.n_stages}")
    print(f"  bytes moved across platforms: {inst.bytes_moved / 1e6:.1f} "
          f"MB ({inst.transfer_s:.3f}s of transfer)")
    print(f"  stage invocations completed: {cp.completed_count}")


def act3_scenario_ab():
    print("\n== 3. split-vs-colocate A/B scenarios ==")
    for sc, tag in ((split_vs_colocate(2e9), "fast WAN"),
                    (split_vs_colocate(3e6, rps=1.0, suffix="-slowwan"),
                     "slow WAN")):
        rep = run_scenario(sc)
        split = rep.per_chain["ab@split"]
        coloc = rep.per_chain["ab@colocate"]
        winner = "split" if split["p90_s"] < coloc["p90_s"] else "colocate"
        print(f"  {tag}: split_p90={split['p90_s']:.2f}s "
              f"colocate_p90={coloc['p90_s']:.2f}s -> {winner} wins "
              f"(split moved {split['bytes_moved'] / 1e9:.2f} GB)")


if __name__ == "__main__":
    act1_planning()
    act2_execution()
    act3_scenario_ab()
