"""End-to-end FDN serving driver (the paper's kind of deployment).

Builds the heterogeneous Function Delivery Network — five target platforms
from small edge boxes to a full pod — deploys both the paper's benchmark
functions and ML-serving functions for the assigned architectures, then
drives a mixed workload through the Gateway and prints where the FDN
delivered every function, the SLO outcomes, and the per-platform energy.

    PYTHONPATH=src python examples/serve_fdn.py
"""
from repro.core import (FDNControlPlane, Gateway, SLOCompositePolicy)
from repro.core import functions as fn_mod
from repro.core import profiles
from repro.core.loadgen import attach_completion_hooks, run_load
from repro.core.types import DeploymentSpec, SLO
from repro.core.deployment import DeploymentGenerator


def main():
    cp = FDNControlPlane(enable_hedging=True, predictive_prewarm=True)
    for prof in profiles.TPU_PLATFORMS.values():
        cp.create_platform(prof)

    # functions: 2 paper-style CPU functions + 3 model-serving functions
    fns = fn_mod.paper_functions()
    serve_fns = {a: fn_mod.serving_function(a).replace(slo=SLO(5.0))
                 for a in ("qwen3-0.6b", "mixtral-8x7b", "llama3-405b")}
    all_fns = list(fns.values()) + list(serve_fns.values())
    fn_mod.seed_object_stores(cp.placement, location="hpc-pod")

    spec = DeploymentSpec("fdn-serve", all_fns, list(cp.platforms))
    spec = DeploymentGenerator(cp.kb, cp.events).annotate(spec)
    cp.deploy(spec)
    attach_completion_hooks(cp)
    cp.policy = SLOCompositePolicy(cp.perf, cp.placement)
    gw = Gateway(cp)

    print("== driving mixed workload through the FDN gateway ==")
    for fn in all_fns:
        run_load(cp.clock, lambda i: gw.request(i), fn, vus=4,
                 duration_s=240.0, sleep_s=0.5)

    print(f"\n{'function':>22s} -> platform decisions")
    by_fn = {}
    for d in cp.kb.decisions:
        by_fn.setdefault(d["fn"], {}).setdefault(d["platform"], 0)
        by_fn[d["fn"]][d["platform"]] += 1
    for fn, plats in by_fn.items():
        top = max(plats, key=plats.get)
        print(f"{fn:>22s} -> {top:14s} ({plats})")

    print(f"\n{'platform':>14s} {'served':>7s} {'P90 s':>8s} {'joules':>10s}")
    for name in cp.platforms:
        print(f"{name:>14s} {cp.metrics.requests_served(name):7d} "
              f"{cp.metrics.p90_response(name):8.3f} "
              f"{cp.energy.joules(name):10.1f}")
    met = sum(1 for i in cp.completed
              if i.response_time is not None
              and i.response_time <= i.fn.slo.p90_response_s)
    print(f"\nSLO-satisfying completions: {met}/{len(cp.completed)} "
          f"hedges={cp.hedge.hedges_sent} "
          f"redelivered={cp.redeliverer.redelivered}")


if __name__ == "__main__":
    main()
