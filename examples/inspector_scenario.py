"""FDNInspector end to end: run one registry scenario, print its report.

A scenario is pure data — platforms, per-function workload mix, policy,
SLOs, faults, seed — and the report is a versioned, canonical-JSON
artifact: run this twice (or on another machine) and the bytes match.

    PYTHONPATH=src python examples/inspector_scenario.py [scenario-name]

Default scenario: mix/five-platform (all five Table-2 functions as
concurrent Poisson streams over all five Table-3 platforms).  List every
registered scenario with ``--list``.
"""
import sys
import time

from repro.inspector import registry, run_scenario


def main(name: str = "mix/five-platform"):
    if name in ("-l", "--list"):
        for n in registry.names():
            print(n)
        return
    sc = registry.get(name)
    print(f"== scenario {sc.name}: {len(sc.platforms)} platforms, "
          f"{len(sc.workloads)} workload streams, {sc.duration_s:.0f}s "
          f"sim, policy={sc.policy}, seed={sc.seed} ==")
    t0 = time.perf_counter()
    rep = run_scenario(sc)
    wall = time.perf_counter() - t0
    t = rep.totals
    print(f"wall time            : {wall:.2f}s "
          f"({t['submitted'] / max(wall, 1e-9):.0f} invocations/s "
          f"simulated)")
    print(f"submitted/completed  : {t['submitted']} / {t['completed']} "
          f"(rejected {t['rejected']})")
    print(f"P50 / P90 / P99      : {t['p50_s']:.3f} / {t['p90_s']:.3f} / "
          f"{t['p99_s']:.3f} s")
    print(f"SLO violation rate   : {100 * t['slo_violation_rate']:.2f}%")
    print(f"cold starts          : {t['cold_starts']}")
    print(f"energy               : {t['energy_wh']:.2f} Wh")
    print(f"decisions / sim-s    : {t['decisions_per_sim_s']:.0f}")
    print("per platform         :")
    for pname, s in rep.per_platform.items():
        print(f"  {pname:>22s} n={s['completed']:7d} "
              f"p90={s['p90_s']:7.3f}s cold={s['cold_starts']:5d} "
              f"{s['energy_wh']:8.2f} Wh")
    print("per function         :")
    for fname, s in rep.per_function.items():
        print(f"  {fname:>22s} n={s['completed']:7d} "
              f"p90={s['p90_s']:7.3f}s (slo {s['slo_s']:.1f}s, "
              f"viol {100 * s['slo_violation_rate']:.2f}%)")
    print(f"report               : {len(rep.to_json())} bytes of "
          f"canonical JSON (schema v{rep.schema_version})")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "mix/five-platform")
