"""Open-loop burst scheduling through the batched FDN fast path.

Drives a Poisson arrival storm (default 100k invocations) through
``Gateway.request_batch``: every sub-window burst is admitted with ONE
vectorized policy evaluation, and results stream into a columnar sink
(no Python object retained per latency sample).  Prints the achieved
admission throughput, SLO outcome, and where the FDN delivered the load.

    PYTHONPATH=src python examples/batch_scheduling.py [n_arrivals]
"""
import sys
import time

from repro.core import FDNControlPlane, Gateway
from repro.core import functions as fn_mod
from repro.core import profiles
from repro.core.loadgen import (ColumnarResultSink, poisson_arrivals,
                                run_arrivals)
from repro.core.types import DeploymentSpec


def main(n_arrivals: int = 100_000):
    cp = FDNControlPlane()
    for prof in profiles.PAPER_PLATFORMS.values():
        cp.create_platform(prof)
    fns = {k: f.replace(real_fn=None)       # analytic: pure scheduling demo
           for k, f in fn_mod.paper_functions().items()}
    fn_mod.seed_object_stores(cp.placement, location="cloud-cluster")
    cp.deploy(DeploymentSpec("burst", list(fns.values()),
                             list(cp.platforms)))
    gw = Gateway(cp)
    sink = ColumnarResultSink(capacity=n_arrivals).install(cp)

    fn = fns["nodeinfo"]
    duration = 600.0
    rps = n_arrivals / duration
    arrivals = poisson_arrivals(rps, duration, seed=42)
    print(f"== {arrivals.size} Poisson arrivals @ {rps:.0f} rps "
          f"over {duration:.0f}s (sim), batch window 50 ms ==")
    t0 = time.perf_counter()
    run_arrivals(cp.clock, gw.request_batch, fn, arrivals,
                 batch_window_s=0.05, sink=sink)
    wall = time.perf_counter() - t0

    print(f"wall time            : {wall:.2f}s "
          f"({arrivals.size / wall:.0f} invocations/s simulated)")
    print(f"completed / rejected : {sink.completed} / {sink.rejected}")
    print(f"P90 response         : {sink.p90_response() * 1e3:.1f} ms "
          f"(SLO {fn.slo.p90_response_s:.1f} s)")
    print(f"cold starts          : {sink.cold_start_count()}")
    print("platform shares      :")
    for name, count in sorted(sink.platform_counts().items(),
                              key=lambda kv: -kv[1]):
        print(f"  {name:>22s} {count:8d} "
              f"({100.0 * count / max(sink.completed, 1):.1f}%)")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 100_000)
