"""Quickstart: train a small model for a few steps, then serve it with the
continuous-batching engine.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, TokenStream
from repro.models import model_api as api
from repro.serving.engine import Request, ServingEngine
from repro.train import optimizer as opt
from repro.train.train_step import make_train_step


def main():
    # ---- 1. pick an architecture (any of the 10 assigned ids works) ----
    cfg = get_config("qwen3-0.6b").reduced()
    print(f"arch={cfg.name} params={api.param_count(cfg):,}")

    # ---- 2. train a few steps on the synthetic pipeline ----
    oc = opt.OptConfig(lr=3e-3, warmup_steps=5, total_steps=30)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    state = opt.init_state(oc, api.model_specs(cfg))
    step = jax.jit(make_train_step(cfg, oc))
    stream = TokenStream(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=8, mean_doc_len=16))
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in stream.batch(i).items()}
        params, state, m = step(params, state, batch)
        if i % 10 == 0:
            print(f"  step {i:3d} loss={float(m['loss']):.3f}")

    # ---- 3. serve it: continuous batching over a shared KV cache ----
    eng = ServingEngine(cfg, params, batch_size=3, max_context=96)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        int(rng.integers(4, 40))
                                        ).astype(np.int32),
                    max_new_tokens=8) for i in range(6)]
    eng.run(reqs)
    print("served:", [len(r.out_tokens) for r in reqs], eng.stats())


if __name__ == "__main__":
    main()
