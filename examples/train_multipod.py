"""Multi-pod training walkthrough: the exact pieces a pod launcher uses —
mesh, shardings, AOT lowering — demonstrated end-to-end, then a real
(reduced-scale) fault-tolerant training run with checkpoint/restart.

    PYTHONPATH=src python examples/train_multipod.py

For the full 512-chip AOT compile of every architecture x shape:
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi
"""
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, TokenStream
from repro.models import model_api as api
from repro.train import optimizer as opt
from repro.train.train_step import make_train_step


def main():
    cfg = get_config("qwen3-1.7b")
    print(f"== {cfg.name}: what the pod launcher assembles ==")
    mspecs = api.model_specs(cfg)
    n = api.param_count(cfg)
    print(f"  parameters: {n:,} ({2 * n / 1e9:.1f} GB bf16)")
    print("  sharding rules (examples):")
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh()
    shardings = api.param_pspecs(cfg, mesh)
    for k in ("embed", "final_norm"):
        print(f"    {k:12s} -> {shardings[k]}")
    lay = shardings["layers"]
    print(f"    attn.wq      -> {lay['attn']['wq']}")
    print(f"    mlp.wi       -> {lay['mlp']['wi']}")
    print("  (on the 16x16 / 2x16x16 production meshes these resolve to "
        "DP x TP shardings; see repro/launch/dryrun.py)")

    # ---- real fault-tolerant training at reduced scale ----
    print("\n== reduced-scale training with checkpoint/restart ==")
    rcfg = cfg.reduced()
    oc = opt.OptConfig(lr=2e-3, warmup_steps=3, total_steps=16)
    params = api.init_params(rcfg, jax.random.PRNGKey(0))
    state = opt.init_state(oc, api.model_specs(rcfg))
    step = jax.jit(make_train_step(rcfg, oc))
    stream = TokenStream(DataConfig(vocab_size=rcfg.vocab_size, seq_len=32,
                                    global_batch=4))
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, retain=2)
        for i in range(8):
            batch = {k: jnp.asarray(v) for k, v in stream.batch(i).items()}
            params, state, m = step(params, state, batch)
        ck.save(8, {"params": params, "opt": state})
        print(f"  step 8 loss={float(m['loss']):.3f}; checkpoint saved")

        # --- simulate a node failure: restart from the checkpoint ---
        restored = ck.restore(8, {"params": params, "opt": state})
        params, state = restored["params"], restored["opt"]
        for i in range(8, 16):
            batch = {k: jnp.asarray(v) for k, v in stream.batch(i).items()}
            params, state, m = step(params, state, batch)
        print(f"  restarted and trained to step 16: "
              f"loss={float(m['loss']):.3f}")


if __name__ == "__main__":
    main()
