"""Warm-pool controller throughput + prewarm-policy A/B claims
(repro.autoscale).

Two measurements:

  * ``tick throughput`` — controller ticks/second over the five Table-3
    platforms x the Table-2 function mix (25 managed rows) under the
    predictive forecaster, with an arrival burst landing every 8th tick:
    the mixed steady-state the dormant fast-forward + cached-decision
    paths are built for.  The full run pins >= 1e5 ticks/s; CI checks the
    pinned floor in ``benchmarks/perf_floor.json`` via ``--check-floor``.
  * ``policy A/B`` — the registry's prewarm-policy studies, asserting the
    energy-vs-SLO trade-off in BOTH directions (seed-deterministic; the
    same numbers are drift-gated by the golden reports):
      - diurnal deep-trough trace: predictive prewarming beats the fixed
        60 s keep-alive on cold-start rate at equal-or-lower idle Wh;
      - sparse trace: scale-to-zero wins idle Wh but pays for it in p99
        (cold start on nearly every arrival);
      - MMPP burst trace: predictive holds equal-or-lower idle Wh.

``--smoke`` runs fewer ticks and only the sparse A/B (the diurnal pair is
covered by the CI golden gate); ``--json PATH`` writes the measurements;
``--check-floor FLOOR.json`` fails when a pinned metric drops more than
30% below its floor.
"""
from __future__ import annotations

import gc
import json
import sys
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks.fdn_common import Row, build_fdn, check

FULL_TICKS = 200_000
SMOKE_TICKS = 50_000
ARRIVAL_EVERY = 8
FLOOR_GRACE = 0.30
TICKS_PER_S_PIN = 1e5


def _bench_ticks(n_ticks: int, reps: int) -> Tuple[float, int]:
    """(ticks/s best-of-reps, managed rows): drives ``controller.tick``
    directly with a synthetic admission stream (counters written the same
    way the platforms write them), isolating the control loop itself."""
    from repro.autoscale import WarmPoolController, make_policy
    cp, _gw, _fns = build_fdn(analytic=True)
    ctl = WarmPoolController(cp.platforms, cp.perf, cp.clock,
                             make_policy("predictive"), tick_s=1.0).attach()
    clock = cp.clock
    p0 = next(iter(cp.platforms.values()))
    for _ in range(256):                   # settle pools / warm caches
        clock._t += 1.0
        ctl.tick()
    best = float("inf")
    for _ in range(reps):
        # collect previous arms' garbage outside the timed region (GC
        # stays ON inside it; the controller allocates nothing per tick)
        gc.collect()
        t0 = time.perf_counter()
        for i in range(n_ticks):
            clock._t += 1.0
            if i % ARRIVAL_EVERY == 0:
                c = p0.autoscale_counts
                c["nodeinfo"] = c.get("nodeinfo", 0) + 5
            ctl.tick()
        best = min(best, time.perf_counter() - t0)
    return n_ticks / best, ctl._rows


def _run_ab(name: str) -> Dict[str, float]:
    from repro.inspector import registry, run_scenario
    t = run_scenario(registry.get(name)).totals
    return {"cold_start_rate": t["cold_start_rate"],
            "cold_starts": t["cold_starts"], "idle_wh": t["idle_wh"],
            "p99_s": t["p99_s"], "completed": t["completed"]}


def _check_parity(failures: List[str]) -> None:
    """NumPy and jax forecaster backends must make byte-identical prewarm
    decisions on a seeded arrival stream."""
    from repro.autoscale import PredictivePolicy
    rng = np.random.default_rng(7)
    rows, ticks = 12, 400
    streams = rng.poisson(2.0, size=(ticks, rows)) * \
        (rng.random(size=(ticks, rows)) < 0.3)
    exec_s = rng.uniform(0.01, 0.5, rows)
    decisions = {}
    for backend in ("numpy", "jax"):
        pol = PredictivePolicy(backend=backend)
        pol.resize(rows)
        pol.set_exec(exec_s, 1.0)
        out = []
        for k in range(ticks):
            counts = streams[k].astype(float)
            desired, ttl = pol.tick(counts, bool(counts.any()))
            out.append((desired.astype(int).tolist(),
                        np.asarray(ttl).astype(int).tolist()))
        decisions[backend] = out
    check(decisions["numpy"] == decisions["jax"],
          "jax forecaster must make byte-identical prewarm decisions to "
          "the NumPy oracle", failures)


def run_bench(smoke: bool = False,
              results_out: Optional[Dict] = None
              ) -> Tuple[List[Row], List[str]]:
    rows: List[Row] = []
    failures: List[str] = []
    n_ticks = SMOKE_TICKS if smoke else FULL_TICKS
    reps = 2 if smoke else 3

    ticks_per_s, n_rows = _bench_ticks(n_ticks, reps)
    rows.append(Row("autoscale/tick_throughput", 1e6 / ticks_per_s,
                    f"ticks_per_s={ticks_per_s:.0f};rows={n_rows};"
                    f"arrival_every={ARRIVAL_EVERY};best_of={reps}"))
    if not smoke:
        check(ticks_per_s >= TICKS_PER_S_PIN,
              f"controller should sustain >= {TICKS_PER_S_PIN:.0e} "
              f"ticks/s (got {ticks_per_s:.0f})", failures)

    # -------------------------------------------------- policy A/B ----
    ab: Dict[str, Dict[str, float]] = {}
    arms = ["sparse-ttl", "sparse-scale-to-zero"]
    if not smoke:
        arms += ["diurnal-ttl", "diurnal-predictive",
                 "burst-ttl", "burst-predictive"]
    for arm in arms:
        ab[arm] = s = _run_ab(f"autoscale/{arm}")
        rows.append(Row(
            f"autoscale/{arm}", 0.0,
            f"cold_rate={s['cold_start_rate']:.4f};"
            f"idle_wh={s['idle_wh']:.4f};p99_s={s['p99_s']:.3f};"
            f"n={s['completed']}"))

    s2z, ttl = ab["sparse-scale-to-zero"], ab["sparse-ttl"]
    check(s2z["idle_wh"] < ttl["idle_wh"],
          "sparse: scale-to-zero should win idle Wh over the fixed TTL",
          failures)
    check(s2z["p99_s"] > ttl["p99_s"],
          "sparse: scale-to-zero should pay for idle Wh with worse p99",
          failures)
    if not smoke:
        pred, ttl = ab["diurnal-predictive"], ab["diurnal-ttl"]
        check(pred["cold_start_rate"] < ttl["cold_start_rate"],
              "diurnal: predictive prewarming should beat the fixed TTL "
              "on cold-start rate", failures)
        check(pred["idle_wh"] <= ttl["idle_wh"],
              "diurnal: predictive should spend equal-or-lower idle Wh "
              "than the fixed TTL", failures)
        check(ab["burst-predictive"]["idle_wh"] <=
              ab["burst-ttl"]["idle_wh"],
              "burst: predictive should hold equal-or-lower idle Wh",
              failures)
        _check_parity(failures)

    if results_out is not None:
        results_out.update({
            "smoke": smoke, "n_ticks": n_ticks, "rows": n_rows,
            "autoscale_ticks_per_s": round(ticks_per_s, 1),
            "ab": ab,
        })
    return rows, failures


def check_floor(results: Dict, floor_path: str,
                failures: List[str]) -> None:
    with open(floor_path) as f:
        floors = json.load(f)
    floor = floors.get("autoscale_ticks_per_s")
    if floor is None:
        return
    got = results["autoscale_ticks_per_s"]
    limit = floor * (1.0 - FLOOR_GRACE)
    check(got >= limit,
          f"perf floor breach: autoscale_ticks_per_s = {got:.0f} < "
          f"{limit:.0f} (floor {floor:.0f} - {FLOOR_GRACE:.0%})", failures)


def main(argv: List[str]) -> int:
    smoke = "--smoke" in argv
    floor_path = None
    json_path = "BENCH_autoscale.json"   # always emitted; --json overrides
    if "--json" in argv:
        json_path = argv[argv.index("--json") + 1]
    if "--check-floor" in argv:
        floor_path = argv[argv.index("--check-floor") + 1]
    results: Dict = {}
    rows, failures = run_bench(smoke=smoke, results_out=results)
    if floor_path is not None:
        check_floor(results, floor_path, failures)
    with open(json_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    for r in rows:
        print(r.csv())
    print("failures:", failures or "none")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
