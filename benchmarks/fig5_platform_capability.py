"""Fig. 5: nodeinfo across VUs in {10, 20, 50} on all five platforms.

Runs through the FDNInspector scenario runner (``registry.fig5_cell``)
instead of a hand-wired control plane — each cell is a declarative
Scenario and the stats come from its ScenarioReport.

Paper claims validated here:
  * edge-cluster is worst on requests/s and P90 at every load;
  * below ~20 VUs the four non-edge platforms perform similarly;
  * at 50 VUs hpc-node-cluster serves the most requests, cloud-cluster the
    fewest among the non-edge platforms (compute capability spread).
"""
from __future__ import annotations

from typing import List, Tuple

from benchmarks.fdn_common import Row, check, scenario_row
from repro.inspector import registry, run_scenario

DURATION = 120.0


def run_bench() -> Tuple[List[Row], List[str]]:
    rows: List[Row] = []
    failures: List[str] = []
    served = {}
    p90 = {}
    for vus in (10, 20, 50):
        for pname in ("hpc-node-cluster", "old-hpc-node-cluster",
                      "cloud-cluster", "google-cloud-cluster",
                      "edge-cluster"):
            rep = run_scenario(registry.fig5_cell(pname, vus, DURATION))
            stats = rep.per_platform[pname]
            rows.append(scenario_row(rep.scenario["name"], stats))
            served[(pname, vus)] = stats["rps"]
            p90[(pname, vus)] = stats["p90_s"]

    non_edge = ("hpc-node-cluster", "old-hpc-node-cluster",
                "cloud-cluster", "google-cloud-cluster")
    for vus in (10, 20, 50):
        check(all(served[("edge-cluster", vus)] <= served[(p, vus)]
                  for p in non_edge),
              f"edge should serve fewest requests at {vus} VUs", failures)
    check(served[("hpc-node-cluster", 50)] ==
          max(served[(p, 50)] for p in non_edge),
          "hpc should serve most at 50 VUs", failures)
    check(served[("cloud-cluster", 50)] ==
          min(served[(p, 50)] for p in non_edge),
          "cloud should serve fewest non-edge at 50 VUs", failures)
    # "similar" at low load: within 2.5x of each other
    lo = [served[(p, 10)] for p in non_edge]
    check(max(lo) / max(min(lo), 1e-9) < 2.5,
          "non-edge platforms should be similar at 10 VUs", failures)
    check(p90[("edge-cluster", 50)] > p90[("hpc-node-cluster", 50)],
          "edge P90 should exceed hpc P90 at 50 VUs", failures)
    return rows, failures


if __name__ == "__main__":
    rows, failures = run_bench()
    for r in rows:
        print(r.csv())
    print("failures:", failures or "none")
