"""Streaming replay at Azure scale: chunked minute columns through the
fused admission step in bounded memory.

Two scales:

  * ``--smoke`` — ``scale/million-burst``: one burst hour, ~10^6
    invocations (the CI peak-RSS gate: a million-arrival burst must NOT
    inflate the resident set, because arrivals never exist as objects);
  * full (default) — the 14-day Azure-trace shape, ~10^8 invocations
    streamed through hour chunks (the array-native-core exit criterion).

Claims checked at both scales:

  * every generated arrival is submitted and decided
    (submitted == admitted + rejected == the trace's total count);
  * the SLO-composite policy admits the whole trace on the five
    Table-3 platforms (analytic predictions: nothing is infeasible);
  * perf-model cells absorbed the folded population (the columnar sink
    actually received the stream);
  * peak RSS stays under ``--rss-limit-mb`` (default 1024) — measured
    with ``resource.getrusage``, so it covers the whole process
    including the trace's count matrix;
  * live telemetry rollups (on by default; ``--no-rollups`` disables)
    fold every admitted row into the multi-resolution tier rings under
    the SAME RSS bound — O(tiers x capacity) rollup state regardless of
    trace length is the engine's headline claim.

``--json PATH`` writes measurements (rows/s, peak RSS, totals) for the
CI artifact."""
from __future__ import annotations

import gc
import json
import resource
import sys
import time
from typing import Dict, List, Optional, Tuple

from benchmarks.fdn_common import Row, build_fdn, check
from repro.inspector.streaming import stream_replay
from repro.inspector.traces import synthetic_azure_counts

FN_MIX = ("nodeinfo", "primes-python", "JSON-loads", "image-processing")
FULL_DAYS = 14
FULL_TOTAL = 100_000_000        # ~10^8: the Azure-trace scale
SMOKE_TOTAL = 1_000_000         # scale/million-burst
CHUNK_MINUTES = 60
DEFAULT_RSS_LIMIT_MB = 1024


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _trace(minutes: int, total: int) -> Dict:
    """Synthetic Azure minute counts sized to ~``total`` arrivals."""
    mean_rpm = total / (len(FN_MIX) * minutes)
    return synthetic_azure_counts(FN_MIX, minutes=minutes,
                                  mean_rpm=mean_rpm, seed=7)


def run_bench(smoke: bool = False,
              rss_limit_mb: float = DEFAULT_RSS_LIMIT_MB,
              results_out: Optional[Dict] = None,
              rollups: bool = True
              ) -> Tuple[List[Row], List[str]]:
    rows: List[Row] = []
    failures: List[str] = []
    label = "million-burst" if smoke else "azure-14d"
    minutes = 60 if smoke else FULL_DAYS * 1440
    counts = _trace(minutes, SMOKE_TOTAL if smoke else FULL_TOTAL)
    total = int(sum(int(c.sum()) for c in counts.values()))

    cp, _gw, fns = build_fdn(analytic=True)
    cp.kb.log_decisions = False
    engine = None
    if rollups:
        from repro.obs.telemetry import TelemetryConfig, TelemetryEngine
        # capacity 4096 lets a whole hour chunk (3600 finest buckets)
        # fold as one vectorized span group instead of 8 ring wraps
        engine = cp.attach_telemetry(
            TelemetryEngine(TelemetryConfig(capacity=4096,
                                            auto_flush_samples=None)))
    gc.collect()
    t0 = time.perf_counter()
    stats = stream_replay(cp, fns, counts, chunk_minutes=CHUNK_MINUTES,
                          seed=7)
    dt = time.perf_counter() - t0
    peak_mb = _peak_rss_mb()
    rate = stats.submitted / max(dt, 1e-9)

    extra = ""
    if engine is not None:
        engine.finalize()
        roll = engine.rollup_summary()
        extra = (f";rollup_samples={roll['samples']}"
                 f";rollup_keys={roll['keys']}")
        check(roll["samples"] == stats.admitted,
              "rollups must fold every admitted row "
              f"(got {roll['samples']}/{stats.admitted})", failures)
    rows.append(Row(f"streaming_replay/{label}", dt / max(total, 1) * 1e6,
                    f"rows_per_s={rate:.0f};submitted={stats.submitted};"
                    f"chunks={stats.chunks};"
                    f"peak_chunk_rows={stats.peak_chunk_rows};"
                    f"peak_rss_mb={peak_mb:.0f}" + extra))

    check(stats.submitted == total,
          f"every trace arrival must be submitted "
          f"(got {stats.submitted}/{total})", failures)
    check(stats.admitted + stats.rejected == stats.submitted,
          "every submission must be decided", failures)
    check(stats.rejected == 0,
          "SLO-composite should admit the whole trace on the Table-3 "
          f"platforms (rejected {stats.rejected})", failures)
    folded = sum(int(cp.perf._state.exec_n[cp.perf._frow[name], :].sum())
                 for name in FN_MIX if name in cp.perf._frow)
    check(folded == stats.admitted,
          "perf-model cells must absorb the folded population "
          f"(folded {folded} != admitted {stats.admitted})", failures)
    check(peak_mb <= rss_limit_mb,
          f"peak RSS {peak_mb:.0f} MB exceeds the {rss_limit_mb:.0f} MB "
          "bound — arrivals are leaking into objects", failures)

    if results_out is not None:
        results_out.update({
            "scale": label, "total": total, "seconds": round(dt, 3),
            "rows_per_s": round(rate, 1), "peak_rss_mb": round(peak_mb, 1),
            "rss_limit_mb": rss_limit_mb,
            "chunk_minutes": CHUNK_MINUTES, **stats.to_dict(),
        })
        if engine is not None:
            results_out["rollup"] = engine.rollup_summary()
    return rows, failures


def main(argv: List[str]) -> int:
    smoke = "--smoke" in argv
    rss_limit = DEFAULT_RSS_LIMIT_MB
    json_path = "BENCH_replay.json"      # always emitted; --json overrides
    if "--rss-limit-mb" in argv:
        rss_limit = float(argv[argv.index("--rss-limit-mb") + 1])
    if "--json" in argv:
        json_path = argv[argv.index("--json") + 1]
    results: Dict = {}
    rows, failures = run_bench(smoke=smoke, rss_limit_mb=rss_limit,
                               results_out=results,
                               rollups="--no-rollups" not in argv)
    with open(json_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    for r in rows:
        print(r.csv())
    print("failures:", failures or "none")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
