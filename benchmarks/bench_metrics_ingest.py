"""Metrics-ingest throughput: columnar completion path vs per-sample adds.

A 10^6-invocation FDNInspector scenario must not pay a per-sample Python
hot path for metrics.  This benchmark ingests the same synthetic
completion set three ways:

  * single-metric arms — ``WindowSeries.add`` per sample vs ONE
    ``ColumnarWindowSeries.add_many`` (the raw series backends);
  * per-completion baseline — the old ``record_completion`` hot path:
    seven ``WindowSeries.add`` calls per completion into the
    (platform, fn, metric)-keyed registry;
  * full bulk path — ``MetricsRegistry.record_completions`` over a
    ``ColumnarResultSink``: the same Table-1 metric set, grouped with
    array masks, one ``add_many`` per (platform, fn, metric).

Claim checked: on identical work (all 7 metrics per completion) the bulk
path sustains >= 5x the per-sample completion throughput, and the
aggregates (count / total / p90) agree across backends.
"""
from __future__ import annotations

import json
import sys
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks.fdn_common import Row, check
from repro.core.loadgen import ColumnarResultSink
from repro.core.monitoring import (ColumnarWindowSeries, MetricsRegistry,
                                   WindowSeries)
from repro.core.types import FunctionSpec

FULL_N = 1_000_000
SMOKE_N = 200_000
WINDOW_S = 10.0
DURATION_S = 600.0


def _synthetic_completions(n: int):
    rng = np.random.default_rng(7)
    arrival = np.sort(rng.uniform(0.0, DURATION_S, n))
    rt = rng.exponential(0.4, n)
    end = arrival + rt
    fns = [FunctionSpec(name="nodeinfo", flops=1e6, memory_mb=128),
           FunctionSpec(name="JSON-loads", flops=1e7, read_bytes=1e5,
                        memory_mb=256)]
    platforms = ["hpc-node-cluster", "edge-cluster"]
    sink = ColumnarResultSink.from_columns(
        arrival, end, platforms, rng.integers(0, len(platforms), n),
        fns, rng.integers(0, len(fns), n), cold=rng.random(n) < 0.01,
        exec_s=rt * 0.8)
    return sink, end, end - arrival


def run_bench(smoke: bool = False,
              results_out: Optional[Dict] = None
              ) -> Tuple[List[Row], List[str]]:
    n = SMOKE_N if smoke else FULL_N
    rows: List[Row] = []
    failures: List[str] = []
    sink, ts, vs = _synthetic_completions(n)

    ws = WindowSeries(WINDOW_S)
    ts_list, vs_list = ts.tolist(), vs.tolist()
    t0 = time.perf_counter()
    for t, v in zip(ts_list, vs_list):
        ws.add(t, v)
    t_base = time.perf_counter() - t0

    cw = ColumnarWindowSeries(WINDOW_S)
    t0 = time.perf_counter()
    cw.add_many(ts, vs)
    t_col = time.perf_counter() - t0

    # per-completion baseline: the old record_completion hot path —
    # seven per-sample adds into the keyed registry, driven from
    # pre-extracted Python scalars (no Invocation construction billed)
    cols = sink.completion_columns()
    pnames = [name for name, _ in sorted(cols["platform_ids"].items(),
                                         key=lambda kv: kv[1])]
    fnames = [name for name, _ in sorted(cols["fn_ids"].items(),
                                         key=lambda kv: kv[1])]
    prow = [pnames[i] for i in cols["platform"].tolist()]
    frow = [fnames[i] for i in cols["fn"].tolist()]
    mem = {f: float(cols["fn_specs"][f].memory_mb) for f in fnames}
    io = {f: cols["fn_specs"][f].read_bytes + cols["fn_specs"][f].write_bytes
          for f in fnames}
    end_l, rt_l = ts.tolist(), vs.tolist()
    exec_l = cols["exec"].tolist()
    cold_l = cols["cold"].tolist()
    reg_seq = MetricsRegistry(WINDOW_S, columnar=False)
    t0 = time.perf_counter()
    for i in range(n):
        p, f, t = prow[i], frow[i], end_l[i]
        reg_seq.add(p, f, "requests", t, 1.0)
        reg_seq.add(p, f, "response_time", t, rt_l[i])
        reg_seq.add(p, f, "invocations", t, 1.0)
        reg_seq.add(p, f, "exec_time", t, exec_l[i])
        if cold_l[i]:
            reg_seq.add(p, f, "cold_starts", t, 1.0)
        reg_seq.add(p, f, "memory_mb", t, mem[f])
        reg_seq.add(p, f, "disk_io", t, io[f])
    t_seq = time.perf_counter() - t0

    reg = MetricsRegistry(WINDOW_S)
    t0 = time.perf_counter()
    reg.record_completions(sink, visible_infra=True)
    t_bulk = time.perf_counter() - t0

    base_rate = n / max(t_base, 1e-9)
    col_rate = n / max(t_col, 1e-9)
    seq_rate = n / max(t_seq, 1e-9)
    bulk_rate = n / max(t_bulk, 1e-9)
    speedup = bulk_rate / max(seq_rate, 1e-9)

    rows.append(Row("metrics_ingest/per_sample_add", t_base / n * 1e6,
                    f"samples_per_s={base_rate:.0f};n={n}"))
    rows.append(Row("metrics_ingest/columnar_add_many", t_col / n * 1e6,
                    f"samples_per_s={col_rate:.0f};"
                    f"speedup={col_rate / max(base_rate, 1e-9):.1f}x"))
    rows.append(Row("metrics_ingest/record_completion_seq", t_seq / n * 1e6,
                    f"completions_per_s={seq_rate:.0f};metrics=7"))
    rows.append(Row("metrics_ingest/record_completions", t_bulk / n * 1e6,
                    f"completions_per_s={bulk_rate:.0f};metrics=7;"
                    f"speedup={speedup:.1f}x"))

    # correctness: both backends agree on the aggregates
    check(cw.count() == ws.count() == n, "sample counts must match",
          failures)
    check(abs(cw.total() - ws.total()) < 1e-6 * max(ws.total(), 1.0),
          "window totals must match", failures)
    check(abs(cw.p90() - ws.p90()) < 1e-9, "p90 must match", failures)
    got = sum(int(reg.total(p, f, "requests"))
              for p in sink.platform_counts()
              for f in sink.fn_counts())
    check(got == n, f"record_completions should ingest every completion "
          f"(got {got}/{n})", failures)
    for p in sink.platform_counts():
        for f in sink.fn_counts():
            a = reg.total(p, f, "exec_time")
            b = reg_seq.total(p, f, "exec_time")
            check(abs(a - b) < 1e-6 * max(abs(b), 1.0),
                  f"bulk vs per-sample exec_time mismatch on {p}/{f}",
                  failures)
    target = 5.0
    check(speedup >= target,
          f"record_completions should be >= {target:.0f}x the per-sample "
          f"record_completion baseline (got {speedup:.1f}x)", failures)

    if results_out is not None:
        results_out.update({
            "n": n, "smoke": smoke,
            "samples_per_s": {
                "per_sample_add": round(base_rate, 1),
                "columnar_add_many": round(col_rate, 1),
            },
            "completions_per_s": {
                "record_completion_seq": round(seq_rate, 1),
                "record_completions": round(bulk_rate, 1),
            },
            "speedup_bulk_vs_seq": round(speedup, 2),
        })
    return rows, failures


def main(argv: List[str]) -> int:
    smoke = "--smoke" in argv
    json_path = "BENCH_metrics.json"     # always emitted; --json overrides
    if "--json" in argv:
        json_path = argv[argv.index("--json") + 1]
    results: Dict = {}
    rows, failures = run_bench(smoke=smoke, results_out=results)
    with open(json_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    for r in rows:
        print(r.csv())
    print("failures:", failures or "none")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
