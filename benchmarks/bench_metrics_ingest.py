"""Metrics-ingest throughput: columnar completion path vs per-sample adds.

A 10^6-invocation FDNInspector scenario must not pay a per-sample Python
hot path for metrics.  This benchmark ingests the same synthetic
completion set three ways:

  * single-metric arms — ``WindowSeries.add`` per sample vs ONE
    ``ColumnarWindowSeries.add_many`` (the raw series backends);
  * per-completion baseline — the old ``record_completion`` hot path:
    seven ``WindowSeries.add`` calls per completion into the
    (platform, fn, metric)-keyed registry;
  * full bulk path — ``MetricsRegistry.record_completions`` over a
    ``ColumnarResultSink``: the same Table-1 metric set, grouped with
    array masks, one ``add_many`` per (platform, fn, metric).

Claim checked: on identical work (all 7 metrics per completion) the bulk
path sustains >= 5x the per-sample completion throughput, and the
aggregates (count / total / p90) agree across backends.

The ``rollup`` arm re-runs the bulk path with a live telemetry engine
subscribed (repro.obs.telemetry) and splits the cost in two: the *tap*
(what every ingest pays while telemetry is on — buffering the
subscribed series) and the *fold* (downsampling into the tier rings,
deferred off the hot path).  Gates, pinned in ``perf_floor.json`` via
``--check-floor`` like the scheduler bench's ``columnar_traced`` arm:
tap overhead <= 15% of plain bulk ingest, fold throughput above its
pinned samples/s floor.
"""
from __future__ import annotations

import json
import sys
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks.fdn_common import Row, check
from repro.core.loadgen import ColumnarResultSink
from repro.core.monitoring import (ColumnarWindowSeries, MetricsRegistry,
                                   WindowSeries)
from repro.core.types import FunctionSpec
from repro.obs.telemetry import TelemetryConfig, TelemetryEngine

FULL_N = 1_000_000
SMOKE_N = 200_000
WINDOW_S = 10.0
DURATION_S = 600.0
FLOOR_GRACE = 0.30           # fail when > 30% below a pinned rate floor


def _synthetic_completions(n: int):
    rng = np.random.default_rng(7)
    arrival = np.sort(rng.uniform(0.0, DURATION_S, n))
    rt = rng.exponential(0.4, n)
    end = arrival + rt
    fns = [FunctionSpec(name="nodeinfo", flops=1e6, memory_mb=128),
           FunctionSpec(name="JSON-loads", flops=1e7, read_bytes=1e5,
                        memory_mb=256)]
    platforms = ["hpc-node-cluster", "edge-cluster"]
    sink = ColumnarResultSink.from_columns(
        arrival, end, platforms, rng.integers(0, len(platforms), n),
        fns, rng.integers(0, len(fns), n), cold=rng.random(n) < 0.01,
        exec_s=rt * 0.8)
    return sink, end, end - arrival


def run_bench(smoke: bool = False,
              results_out: Optional[Dict] = None
              ) -> Tuple[List[Row], List[str]]:
    n = SMOKE_N if smoke else FULL_N
    rows: List[Row] = []
    failures: List[str] = []
    sink, ts, vs = _synthetic_completions(n)

    ws = WindowSeries(WINDOW_S)
    ts_list, vs_list = ts.tolist(), vs.tolist()
    t0 = time.perf_counter()
    for t, v in zip(ts_list, vs_list):
        ws.add(t, v)
    t_base = time.perf_counter() - t0

    cw = ColumnarWindowSeries(WINDOW_S)
    t0 = time.perf_counter()
    cw.add_many(ts, vs)
    t_col = time.perf_counter() - t0

    # per-completion baseline: the old record_completion hot path —
    # seven per-sample adds into the keyed registry, driven from
    # pre-extracted Python scalars (no Invocation construction billed)
    cols = sink.completion_columns()
    pnames = [name for name, _ in sorted(cols["platform_ids"].items(),
                                         key=lambda kv: kv[1])]
    fnames = [name for name, _ in sorted(cols["fn_ids"].items(),
                                         key=lambda kv: kv[1])]
    prow = [pnames[i] for i in cols["platform"].tolist()]
    frow = [fnames[i] for i in cols["fn"].tolist()]
    mem = {f: float(cols["fn_specs"][f].memory_mb) for f in fnames}
    io = {f: cols["fn_specs"][f].read_bytes + cols["fn_specs"][f].write_bytes
          for f in fnames}
    end_l, rt_l = ts.tolist(), vs.tolist()
    exec_l = cols["exec"].tolist()
    cold_l = cols["cold"].tolist()
    reg_seq = MetricsRegistry(WINDOW_S, columnar=False)
    t0 = time.perf_counter()
    for i in range(n):
        p, f, t = prow[i], frow[i], end_l[i]
        reg_seq.add(p, f, "requests", t, 1.0)
        reg_seq.add(p, f, "response_time", t, rt_l[i])
        reg_seq.add(p, f, "invocations", t, 1.0)
        reg_seq.add(p, f, "exec_time", t, exec_l[i])
        if cold_l[i]:
            reg_seq.add(p, f, "cold_starts", t, 1.0)
        reg_seq.add(p, f, "memory_mb", t, mem[f])
        reg_seq.add(p, f, "disk_io", t, io[f])
    t_seq = time.perf_counter() - t0

    # bulk vs rollup-tapped bulk: best-of-2 with a fresh registry per
    # rep — the tap-overhead gate is a ratio of two fast runs, and one
    # cold first pass (allocator + numpy warmup) can swamp a 15% margin
    # at smoke scale
    def _time_bulk(telemetry: bool):
        best, keep = float("inf"), None
        for _ in range(2):
            r = MetricsRegistry(WINDOW_S)
            eng = None
            if telemetry:
                # capacity 1024 keeps all DURATION_S 1 s buckets live
                # for the correctness checks below (nothing evicted)
                eng = TelemetryEngine(TelemetryConfig(
                    capacity=1024, auto_flush_samples=None))
                r.telemetry = eng
            t0 = time.perf_counter()
            r.record_completions(sink, visible_infra=True)
            dt = time.perf_counter() - t0
            if dt < best:
                best, keep = dt, (r, eng)
        return best, keep

    t_bulk, (reg, _none) = _time_bulk(telemetry=False)
    t_tap, (reg_tel, engine) = _time_bulk(telemetry=True)
    # the fold is off the hot path: tier downsampling, timed separately
    t0 = time.perf_counter()
    folded = engine.flush()
    t_fold = time.perf_counter() - t0

    base_rate = n / max(t_base, 1e-9)
    col_rate = n / max(t_col, 1e-9)
    seq_rate = n / max(t_seq, 1e-9)
    bulk_rate = n / max(t_bulk, 1e-9)
    speedup = bulk_rate / max(seq_rate, 1e-9)

    rows.append(Row("metrics_ingest/per_sample_add", t_base / n * 1e6,
                    f"samples_per_s={base_rate:.0f};n={n}"))
    rows.append(Row("metrics_ingest/columnar_add_many", t_col / n * 1e6,
                    f"samples_per_s={col_rate:.0f};"
                    f"speedup={col_rate / max(base_rate, 1e-9):.1f}x"))
    rows.append(Row("metrics_ingest/record_completion_seq", t_seq / n * 1e6,
                    f"completions_per_s={seq_rate:.0f};metrics=7"))
    rows.append(Row("metrics_ingest/record_completions", t_bulk / n * 1e6,
                    f"completions_per_s={bulk_rate:.0f};metrics=7;"
                    f"speedup={speedup:.1f}x"))
    tap_rate = n / max(t_tap, 1e-9)
    fold_rate = folded / max(t_fold, 1e-9)
    tap_overhead = t_tap / max(t_bulk, 1e-9) - 1.0
    rows.append(Row("metrics_ingest/rollup_tapped", t_tap / n * 1e6,
                    f"completions_per_s={tap_rate:.0f};"
                    f"overhead={tap_overhead * 100:.1f}%"))
    rows.append(Row("metrics_ingest/rollup_fold", t_fold / max(folded, 1)
                    * 1e6, f"samples_per_s={fold_rate:.0f};"
                    f"folded={folded}"))

    # correctness: both backends agree on the aggregates
    check(cw.count() == ws.count() == n, "sample counts must match",
          failures)
    check(abs(cw.total() - ws.total()) < 1e-6 * max(ws.total(), 1.0),
          "window totals must match", failures)
    check(abs(cw.p90() - ws.p90()) < 1e-9, "p90 must match", failures)
    got = sum(int(reg.total(p, f, "requests"))
              for p in sink.platform_counts()
              for f in sink.fn_counts())
    check(got == n, f"record_completions should ingest every completion "
          f"(got {got}/{n})", failures)
    for p in sink.platform_counts():
        for f in sink.fn_counts():
            a = reg.total(p, f, "exec_time")
            b = reg_seq.total(p, f, "exec_time")
            check(abs(a - b) < 1e-6 * max(abs(b), 1.0),
                  f"bulk vs per-sample exec_time mismatch on {p}/{f}",
                  failures)
    target = 5.0
    check(speedup >= target,
          f"record_completions should be >= {target:.0f}x the per-sample "
          f"record_completion baseline (got {speedup:.1f}x)", failures)
    # rollup correctness: every subscribed sample reaches the tier rings
    # (response_time for all completions + cold_starts for the cold ones)
    expect_folded = n + int(cols["cold"].sum())
    check(folded == expect_folded,
          f"rollup should fold every subscribed sample "
          f"(got {folded}/{expect_folded})", failures)
    check(sum(int(engine.series[k].tiers[0].counts.sum())
              for k in engine.keys() if k[2] == "response_time") == n,
          "finest-tier response_time counts must cover every completion",
          failures)

    if results_out is not None:
        results_out.update({
            "n": n, "smoke": smoke,
            "samples_per_s": {
                "per_sample_add": round(base_rate, 1),
                "columnar_add_many": round(col_rate, 1),
            },
            "completions_per_s": {
                "record_completion_seq": round(seq_rate, 1),
                "record_completions": round(bulk_rate, 1),
                "rollup_tapped": round(tap_rate, 1),
            },
            "speedup_bulk_vs_seq": round(speedup, 2),
            "rollup": {
                "tap_overhead_frac": round(tap_overhead, 4),
                "fold_samples_per_s": round(fold_rate, 1),
                "folded_samples": int(folded),
            },
        })
    return rows, failures


def check_floor(results: Dict, floor_path: str,
                failures: List[str]) -> None:
    """Enforce the pinned rollup gates from ``perf_floor.json``: the
    tap-overhead ceiling is absolute, the fold-rate floor gets the same
    30% cold-runner grace as the scheduler floors."""
    with open(floor_path) as f:
        floors = json.load(f).get("metrics_ingest", {})
    if not floors:
        return
    rollup = results.get("rollup", {})
    max_overhead = floors.get("rollup_tap_max_overhead_frac")
    if max_overhead is not None:
        got = rollup.get("tap_overhead_frac", 0.0)
        check(got <= max_overhead,
              f"telemetry tap overhead {got * 100:.1f}% exceeds the "
              f"{max_overhead * 100:.0f}% ceiling", failures)
    fold_floor = floors.get("rollup_fold_samples_per_s")
    if fold_floor is not None:
        limit = fold_floor * (1.0 - FLOOR_GRACE)
        got = rollup.get("fold_samples_per_s", 0.0)
        check(got >= limit,
              f"rollup fold {got:.0f} samples/s below pinned floor "
              f"{fold_floor:.0f} (grace limit {limit:.0f})", failures)


def main(argv: List[str]) -> int:
    smoke = "--smoke" in argv
    json_path = "BENCH_metrics.json"     # always emitted; --json overrides
    if "--json" in argv:
        json_path = argv[argv.index("--json") + 1]
    results: Dict = {}
    rows, failures = run_bench(smoke=smoke, results_out=results)
    if "--check-floor" in argv:
        check_floor(results, argv[argv.index("--check-floor") + 1],
                    failures)
    with open(json_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    for r in rows:
        print(r.csv())
    print("failures:", failures or "none")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
