"""Shared FDNInspector harness for the paper-figure benchmarks.

Builds a control plane with the five Table-3 platforms, deploys the Table-2
functions, seeds the object stores (MinIO analogues: one local, one in
us-east), and provides the measurement/report helpers every fig*.py uses.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import (FDNControlPlane, Gateway, Invocation,
                        WeightedCollaboration, RoundRobinCollaboration)
from repro.core import profiles as prof_mod
from repro.core import functions as fn_mod
from repro.core.loadgen import (LoadResult, attach_completion_hooks,
                                run_load, run_open_loop)
from repro.core.types import DeploymentSpec

IMAGE_KEY = "images/sample.jpg"
JSON_KEY = "json/coords.json"
REMOTE_STORE = "gcp-us-east"


def build_fdn(policy=None, platforms: Optional[List[str]] = None,
              data_location: str = "cloud-cluster",
              analytic: bool = False) -> Tuple[
                  FDNControlPlane, Gateway, Dict]:
    """``analytic=True`` strips the real JAX callables so execution cost
    comes from the analytic model only — scheduler-focused benchmarks must
    not fold one-off JIT compilation into their measurement."""
    cp = FDNControlPlane(policy=policy)
    names = platforms or list(prof_mod.PAPER_PLATFORMS)
    for name in names:
        cp.create_platform(prof_mod.PAPER_PLATFORMS[name])
    fns = fn_mod.paper_functions(IMAGE_KEY, JSON_KEY)
    if analytic:
        fns = {k: f.replace(real_fn=None) for k, f in fns.items()}
    fn_mod.seed_object_stores(cp.placement, IMAGE_KEY, JSON_KEY,
                              location=data_location)
    # remote MinIO instance on GCP us-east (Fig. 11)
    cp.placement.add_store(REMOTE_STORE)
    fn_mod.seed_object_stores(cp.placement, IMAGE_KEY, JSON_KEY,
                              location=REMOTE_STORE)
    # WAN bandwidth Germany <-> us-east (the paper's cross-region latency)
    for name in names:
        cp.placement.set_bandwidth(name, REMOTE_STORE, 2e6)
    spec = DeploymentSpec("fdninspector", list(fns.values()), names)
    cp.deploy(spec)
    attach_completion_hooks(cp)
    gw = Gateway(cp)
    return cp, gw, fns


def run_on_platform(cp: FDNControlPlane, gw: Gateway, fn, platform: str,
                    vus: int, duration_s: float = 120.0,
                    sleep_s: float = 0.05, seed: int = 42) -> LoadResult:
    """Exclusive execution on one platform (paper's per-platform tests)."""
    return run_load(cp.clock,
                    lambda inv: cp.submit(inv, platform_override=platform),
                    fn, vus, duration_s, sleep_s, seed=seed)


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def result_row(name: str, res: LoadResult, duration: float,
               extra: str = "") -> Row:
    comp = res.completed
    mean_rt = (sum(i.response_time for i in comp) / len(comp)
               if comp else float("nan"))
    derived = (f"p90_s={res.p90_response():.3f};"
               f"rps={res.requests_per_s(duration):.1f};n={len(comp)}")
    if extra:
        derived += ";" + extra
    return Row(name, mean_rt * 1e6, derived)


def scenario_row(name: str, stats: Dict, extra: str = "") -> Row:
    """CSV row from one ScenarioReport per-platform/per-function entry."""
    derived = (f"p90_s={stats['p90_s']:.3f};"
               f"rps={stats['rps']:.1f};n={stats['completed']}")
    if extra:
        derived += ";" + extra
    return Row(name, stats["mean_s"] * 1e6, derived)


class CheckFailure(AssertionError):
    pass


def check(cond: bool, msg: str, failures: List[str]):
    if not cond:
        failures.append(msg)
    return cond
