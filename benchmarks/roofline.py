import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline analysis (deliverable g): three terms per (arch x shape) on the
single-pod mesh, derived from the compiled dry-run artifact.

Methodology (see DESIGN.md §5): models scan over layers, so
``compiled.cost_analysis()`` reports per-device FLOPs/bytes with the scan
body counted ONCE. We therefore lower each cell at depth d1/d2 (same
widths), fit cost(d) = base + d*per_unit, and extrapolate to the full depth.
Collective bytes (parsed from post-SPMD HLO) get the same fit. Train cells
are calibrated at microbatches=1 (grad-accumulation scan would otherwise be
single-counted too; arithmetic totals are unchanged by microbatching).

Hardware constants (v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--arch A] [--out F]
"""
import argparse
import dataclasses
import json
import sys
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def fit_cell(cfg, shape, mesh, d1: int = 1, d2: int = 2) -> Optional[Dict]:
    """Two-point depth fit -> extrapolated per-device totals."""
    from repro.launch import dryrun_lib as dl
    units = dl.full_depth_units(cfg)
    # Calibration lowers run fully UNROLLED (every lax.scan iteration present
    # in HLO) so cost_analysis counts true totals; d1/d2 then isolate the
    # per-layer cost. The full-depth dry-run keeps scans rolled.
    c1 = dl.with_depth(cfg, d1).replace(unroll_scans=True)
    c2 = dl.with_depth(cfg, d2).replace(unroll_scans=True)
    r1 = dl.lower_cell(c1, shape, mesh, microbatches=1)
    r2 = dl.lower_cell(c2, shape, mesh, microbatches=1)
    if not (r1.ok and r2.ok):
        return {"ok": False, "error": r1.error or r2.error}

    def extrap(v1, v2):
        per = (v2 - v1) / (d2 - d1)
        base = v1 - d1 * per
        return base + units * per

    return {
        "ok": True,
        "units": units,
        "flops_per_dev": extrap(r1.flops_per_dev, r2.flops_per_dev),
        "bytes_per_dev": extrap(r1.bytes_per_dev, r2.bytes_per_dev),
        "coll_bytes_per_dev": extrap(r1.coll_bytes_per_dev,
                                     r2.coll_bytes_per_dev),
        "coll_kinds_d2": r2.coll_detail["bytes_by_kind"],
        "compile_s": r1.compile_s + r2.compile_s,
    }


def model_flops(cfg, shape) -> float:
    """Analytic 'useful' FLOPs: 6*N*D train, 2*N_active*D inference."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch          # decode: one token per row


def analyse(cell_fit: Dict, cfg, shape, n_chips: int) -> Dict:
    f = cell_fit["flops_per_dev"]
    b = cell_fit["bytes_per_dev"]
    c = cell_fit["coll_bytes_per_dev"]
    t_comp = f / PEAK_FLOPS
    t_mem = b / HBM_BW
    t_coll = c / LINK_BW
    dom = max(("compute", t_comp), ("memory", t_mem),
              ("collective", t_coll), key=lambda kv: kv[1])
    mf = model_flops(cfg, shape)
    hlo_global = f * n_chips
    bound = max(t_comp, t_mem, t_coll)
    return {
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dom[0],
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        # fraction of roofline: useful work per chip-second at the bound
        "roofline_frac": (mf / n_chips / PEAK_FLOPS) / bound if bound else 0,
    }


def run(archs=None, shapes=None, out="results/roofline.json",
        overrides: Optional[Dict] = None) -> Dict:
    from repro.configs.base import ALL_SHAPES, shape_applicable
    from repro.configs.registry import ARCH_IDS, get_config
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=False)
    n_chips = 256
    rows = {}
    for arch in (archs or ARCH_IDS):
        cfg = get_config(arch)
        if overrides:
            cfg = cfg.replace(**overrides)
        for shape in (shapes or ALL_SHAPES):
            ok, reason = shape_applicable(cfg, shape)
            key = f"{arch}|{shape.name}"
            if not ok:
                rows[key] = {"ok": False, "skip": reason}
                continue
            fit = fit_cell(cfg, shape, mesh)
            if not fit.get("ok"):
                rows[key] = fit
                print(f"FAIL {key}: {fit.get('error', '')[:160]}",
                      flush=True)
                continue
            stats = analyse(fit, cfg, shape, n_chips)
            rows[key] = {**fit, **stats}
            print(f"{arch:22s} {shape.name:12s} "
                  f"comp={stats['compute_s']:9.3e} "
                  f"mem={stats['memory_s']:9.3e} "
                  f"coll={stats['collective_s']:9.3e} "
                  f"dom={stats['dominant']:10s} "
                  f"useful={stats['useful_ratio']:6.3f} "
                  f"roofline={stats['roofline_frac']:6.3f}", flush=True)
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {out}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append")
    ap.add_argument("--shape", action="append")
    ap.add_argument("--out", default="results/roofline.json")
    args = ap.parse_args(argv)
    shapes = None
    if args.shape:
        from repro.configs.registry import get_shape
        shapes = [get_shape(s) for s in args.shape]
    run(args.arch, shapes, args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
