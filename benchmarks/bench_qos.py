"""QoS + overload-resilience A/B: DRR vs FIFO under a burst storm, and
graceful load-shedding under sustained overload.

Runs the registered ``qos/*`` scenarios end to end (identical arrival
streams per pair — same seeds, same workload mixes) and checks the
headline claims of the QoS layer:

  * ``qos/burst-storm-drr`` vs ``qos/burst-storm-fifo`` — the same
    MMPP burst storm drained with weighted deficit-round-robin (8:3:1)
    vs a pure FIFO (uniform weights).  DRR must hold the
    latency_critical class's p99 and SLO-violation rate far below the
    FIFO arm's, while still serving the batch class (no starvation);
    the FIFO arm must actually violate under the storm, so the A/B is
    not vacuous.
  * ``qos/overload-shed`` — admission control under a ramp that
    saturates the fleet: batch (and then standard) rows are shed at
    ingress, latency_critical is never shed and keeps a low violation
    rate.
  * ``qos/brownout-energy-cap`` — an energy cap below the fleet's
    loaded power: brownout mode sheds ONLY the batch class while
    latency_critical stays within SLO.

Measurements land in ``BENCH_qos.json`` (``--json PATH`` overrides);
the scenarios are seeded, so the asserted margins are deterministic on
a given NumPy version.
"""
from __future__ import annotations

import json
import sys
import time
from typing import Dict, List, Optional, Tuple

from benchmarks.fdn_common import Row, check

CLS = ("latency_critical", "standard", "batch")


def _run(name: str) -> Tuple[Dict, float]:
    from repro.inspector import registry, run_scenario
    t0 = time.perf_counter()
    report = run_scenario(registry.get(name))
    return report.qos, time.perf_counter() - t0


def _rows_for(name: str, qos: Dict, wall: float, rows: List[Row]):
    per_class = qos["per_class"]
    shed = qos["admission"]["shed_by_class"]
    for cls in CLS:
        s = per_class[cls]
        rows.append(Row(f"qos/{name.split('/')[1]}/{cls}",
                        wall / max(s["completed"], 1) * 1e6,
                        f"p99_s={s['p99_s']:.3f};"
                        f"viol={s['slo_violation_rate']:.3f};"
                        f"share={s['served_share']:.3f};"
                        f"shed={shed[cls]}"))


def run_bench(smoke: bool = False,
              results_out: Optional[Dict] = None
              ) -> Tuple[List[Row], List[str]]:
    rows: List[Row] = []
    failures: List[str] = []
    out: Dict[str, Dict] = {}
    for name in ("qos/burst-storm-drr", "qos/burst-storm-fifo",
                 "qos/overload-shed", "qos/brownout-energy-cap"):
        qos, wall = _run(name)
        out[name] = qos
        _rows_for(name, qos, wall, rows)

    drr = out["qos/burst-storm-drr"]["per_class"]["latency_critical"]
    fifo = out["qos/burst-storm-fifo"]["per_class"]["latency_critical"]
    drr_batch = out["qos/burst-storm-drr"]["per_class"]["batch"]
    rows.append(Row("qos/drr_vs_fifo", 0.0,
                    f"lc_p99_drr={drr['p99_s']:.2f};"
                    f"lc_p99_fifo={fifo['p99_s']:.2f};"
                    f"lc_viol_drr={drr['slo_violation_rate']:.3f};"
                    f"lc_viol_fifo={fifo['slo_violation_rate']:.3f};"
                    f"batch_share_drr={drr_batch['served_share']:.3f}"))

    # the A/B is only meaningful if the FIFO arm actually melts down
    check(fifo["slo_violation_rate"] >= 0.3,
          "burst storm should overload the FIFO arm's latency_critical "
          f"class (got viol={fifo['slo_violation_rate']:.3f})", failures)
    check(drr["slo_violation_rate"] <= 0.5 * fifo["slo_violation_rate"],
          "DRR should at least halve the FIFO latency_critical violation "
          f"rate (got {drr['slo_violation_rate']:.3f} vs "
          f"{fifo['slo_violation_rate']:.3f})", failures)
    check(drr["p99_s"] <= 0.6 * fifo["p99_s"],
          "DRR should hold latency_critical p99 well under FIFO's "
          f"(got {drr['p99_s']:.2f}s vs {fifo['p99_s']:.2f}s)", failures)
    check(drr_batch["completed"] > 0
          and drr_batch["served_share"] >= 0.15,
          "DRR must not starve the batch class (got share="
          f"{drr_batch['served_share']:.3f})", failures)

    adm = out["qos/overload-shed"]["admission"]
    lc = out["qos/overload-shed"]["per_class"]["latency_critical"]
    check(adm["shed_by_class"]["latency_critical"] == 0,
          "overload shedding must never drop latency_critical rows "
          f"(got {adm['shed_by_class']['latency_critical']})", failures)
    check(adm["shed_by_class"]["batch"] > 0,
          "sustained overload should shed batch rows at ingress "
          f"(got {adm['shed_by_class']['batch']})", failures)
    check(lc["slo_violation_rate"] <= 0.15,
          "with shedding on, latency_critical should stay within SLO "
          f"(got viol={lc['slo_violation_rate']:.3f})", failures)

    brown = out["qos/brownout-energy-cap"]["admission"]
    check(brown["brownout_events"] > 0
          and brown["brownout_shed"]["batch"] > 0
          and brown["brownout_shed"]["latency_critical"] == 0
          and brown["brownout_shed"]["standard"] == 0,
          "the energy cap should trip brownout mode and shed ONLY the "
          f"batch class (got {brown['brownout_shed']})", failures)

    if results_out is not None:
        results_out.update({
            "smoke": smoke,
            "drr_vs_fifo": {
                "lc_p99_drr_s": round(drr["p99_s"], 3),
                "lc_p99_fifo_s": round(fifo["p99_s"], 3),
                "lc_viol_drr": round(drr["slo_violation_rate"], 4),
                "lc_viol_fifo": round(fifo["slo_violation_rate"], 4),
                "batch_share_drr": round(drr_batch["served_share"], 4),
            },
            "overload_shed": {k: dict(v) if isinstance(v, dict) else v
                              for k, v in adm.items()},
            "brownout": {k: dict(v) if isinstance(v, dict) else v
                         for k, v in brown.items()},
        })
    return rows, failures


def main(argv: List[str]) -> int:
    smoke = "--smoke" in argv
    json_path = "BENCH_qos.json"
    if "--json" in argv:
        json_path = argv[argv.index("--json") + 1]
    results: Dict = {}
    rows, failures = run_bench(smoke=smoke, results_out=results)
    with open(json_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    for r in rows:
        print(r.csv())
    print("failures:", failures or "none")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
