"""Fig. 10: primes-python at 40 VUs — exclusive old-hpc, exclusive cloud,
round-robin collaboration, and weighted (5:1) collaboration.

Paper claims validated here:
  * cloud-only is the worst scenario (lowest throughput);
  * round-robin collaboration beats cloud-only on throughput;
  * weighted (old-hpc:cloud = 5:1) is the best of the four scenarios;
  * weighted P90 <= round-robin P90.
"""
from __future__ import annotations

from typing import List, Tuple

from benchmarks.fdn_common import (Row, build_fdn, check, result_row,
                                   run_on_platform)
from repro.core import RoundRobinCollaboration, WeightedCollaboration
from repro.core.loadgen import run_load

DURATION = 120.0
PAIR = ["old-hpc-node-cluster", "cloud-cluster"]


def _run_collab(policy) -> Tuple:
    cp, gw, fns = build_fdn(platforms=PAIR)
    gw.lb_policy = policy
    res = run_load(cp.clock, lambda inv: gw.request(inv),
                   fns["primes-python"], 40, DURATION, sleep_s=0.05)
    return res


def run_bench() -> Tuple[List[Row], List[str]]:
    rows: List[Row] = []
    failures: List[str] = []
    stats = {}

    for pname in PAIR:
        cp, gw, fns = build_fdn(platforms=PAIR)
        res = run_on_platform(cp, gw, fns["primes-python"], pname, 40,
                              DURATION, sleep_s=0.05)
        rows.append(result_row(f"fig10/exclusive/{pname}", res, DURATION))
        stats[pname] = (res.p90_response(), res.requests_per_s(DURATION))

    res = _run_collab(RoundRobinCollaboration())
    rows.append(result_row("fig10/round_robin", res, DURATION))
    stats["rr"] = (res.p90_response(), res.requests_per_s(DURATION))

    res = _run_collab(WeightedCollaboration(
        {"old-hpc-node-cluster": 5, "cloud-cluster": 1}))
    rows.append(result_row("fig10/weighted_5to1", res, DURATION))
    stats["weighted"] = (res.p90_response(), res.requests_per_s(DURATION))

    cloud_rps = stats["cloud-cluster"][1]
    check(cloud_rps == min(v[1] for v in stats.values()),
          "cloud-only should be the worst scenario", failures)
    check(stats["rr"][1] > cloud_rps,
          "round-robin should beat cloud-only throughput", failures)
    check(stats["weighted"][1] >= stats["rr"][1],
          "weighted should serve at least round-robin's throughput",
          failures)
    check(stats["weighted"][0] <= stats["rr"][0] * 1.05,
          "weighted P90 should not exceed round-robin P90", failures)
    return rows, failures


if __name__ == "__main__":
    rows, failures = run_bench()
    for r in rows:
        print(r.csv())
    print("failures:", failures or "none")
