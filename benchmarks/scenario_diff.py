"""Scenario-diff: compare two canonical ScenarioReport JSONs.

Reports are byte-identical for identical code (the runner is seed-
deterministic), so cross-PR regression tracking reduces to: run the same
scenario on both sides, diff the reports with per-metric relative
tolerances, fail loudly on drift.

    python benchmarks/run.py scenario-diff a.json b.json
    python benchmarks/run.py scenario-diff a.json b.json \
        --tol 0.05 --tol p90_s=0.15

Exit status: 0 when every compared metric is within tolerance, 1 on any
drift (missing metrics count as drift).  NaN-vs-NaN compares equal (empty
percentile slots).  Non-numeric leaves (placement maps, modes, names)
must match exactly.

One asymmetry: a whole report *section* present in the new report but
absent from the golden is a warning, not drift — newer code grows report
sections (e.g. ``latency_breakdown``) before the goldens are re-blessed,
and that must not fail every open PR.  A section the golden has but the
new report dropped is still drift.
"""
from __future__ import annotations

import json
import math
import sys
from typing import Any, Dict, List, Tuple

# Per-metric relative tolerances; ``*`` is the fallback.  Percentile tails
# get head-room (a handful of samples move them), counters are tight.
DEFAULT_TOL = 0.05
DEFAULT_TOLERANCES: Dict[str, float] = {
    "*": DEFAULT_TOL,
    "p99_s": 0.15,
    "p90_s": 0.10,
    "rps": 0.05,
    "slo_violation_rate": 0.10,
    "slo_violations": 0.10,
    "cold_starts": 0.10,
    "energy_wh": 0.05,
    "energy_j": 0.05,
    "decisions_per_sim_s": 0.05,
    "transfer_s": 0.10,
    "bytes_moved": 0.05,
    "est_makespan_s": 0.10,
    # exact-match metadata
    "schema_version": 0.0,
    "sim_duration_s": 0.0,
    "slo_s": 0.0,
}

# the scenario spec echo is configuration, not measurement: only the name
# participates in the diff (comparing reports of two different scenarios
# is almost certainly an operator error)
SECTIONS = ("totals", "per_platform", "per_function", "per_chain",
            "latency_breakdown")


class Drift:
    def __init__(self, path: str, a: Any, b: Any, rel: float, tol: float):
        self.path, self.a, self.b, self.rel, self.tol = path, a, b, rel, tol

    def __str__(self):
        rel = "n/a" if math.isnan(self.rel) else f"{self.rel:.4f}"
        return (f"DRIFT {self.path}: a={self.a!r} b={self.b!r} "
                f"rel={rel} tol={self.tol:g}")


def _tol_for(key: str, tolerances: Dict[str, float]) -> float:
    return tolerances.get(key, tolerances.get("*", DEFAULT_TOL))


def _rel_diff(a: float, b: float) -> float:
    if a == b:
        return 0.0
    denom = max(abs(a), abs(b))
    return abs(a - b) / denom if denom else 0.0


def _compare_leaf(path: str, key: str, a: Any, b: Any,
                  tolerances: Dict[str, float], out: List[Drift]):
    if isinstance(a, bool) or isinstance(b, bool) or \
            not isinstance(a, (int, float)) or \
            not isinstance(b, (int, float)):
        if a != b:
            out.append(Drift(path, a, b, float("nan"), 0.0))
        return
    fa, fb = float(a), float(b)
    if math.isnan(fa) and math.isnan(fb):
        return
    if math.isnan(fa) != math.isnan(fb):
        out.append(Drift(path, a, b, float("nan"),
                         _tol_for(key, tolerances)))
        return
    tol = _tol_for(key, tolerances)
    rel = _rel_diff(fa, fb)
    if rel > tol:
        out.append(Drift(path, a, b, rel, tol))


def _compare_tree(path: str, key: str, a: Any, b: Any,
                  tolerances: Dict[str, float], out: List[Drift]):
    if isinstance(a, dict) and isinstance(b, dict):
        for k in sorted(set(a) | set(b)):
            sub = f"{path}.{k}"
            if k not in a or k not in b:
                missing = "a" if k not in a else "b"
                out.append(Drift(sub, a.get(k, "<missing>"),
                                 b.get(k, "<missing>"), float("nan"), 0.0))
                continue
            _compare_tree(sub, k, a[k], b[k], tolerances, out)
        return
    if isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
        if len(a) != len(b):
            out.append(Drift(path, f"len={len(a)}", f"len={len(b)}",
                             float("nan"), 0.0))
            return
        for i, (xa, xb) in enumerate(zip(a, b)):
            _compare_tree(f"{path}[{i}]", key, xa, xb, tolerances, out)
        return
    _compare_leaf(path, key, a, b, tolerances, out)


def diff_reports(a: Dict[str, Any], b: Dict[str, Any],
                 tolerances: Dict[str, float] = None,
                 warnings: List[str] = None) -> List[Drift]:
    """All out-of-tolerance metrics between two report dicts.

    ``a`` is the fresh report, ``b`` the golden.  A section only ``a``
    has is appended to ``warnings`` (when given) instead of drifting —
    see the module docstring."""
    tolerances = {**DEFAULT_TOLERANCES, **(tolerances or {})}
    out: List[Drift] = []
    _compare_leaf("schema_version", "schema_version",
                  a.get("schema_version"), b.get("schema_version"),
                  tolerances, out)
    name_a = (a.get("scenario") or {}).get("name")
    name_b = (b.get("scenario") or {}).get("name")
    if name_a != name_b:
        out.append(Drift("scenario.name", name_a, name_b,
                         float("nan"), 0.0))
    for section in SECTIONS:
        sa, sb = a.get(section), b.get(section)
        if sa is None and sb is None:
            continue
        if section in a and section not in b:
            if warnings is not None:
                warnings.append(
                    f"section {section!r} is new (absent from the golden)"
                    " — tolerated; re-bless the golden to start gating it")
            continue
        _compare_tree(section, section, sa or {}, sb or {},
                      tolerances, out)
    return out


USAGE = ("usage: scenario-diff a.json b.json [--tol X] [--tol metric=X]")


def _parse_args(argv: List[str]) -> Tuple[str, str, Dict[str, float]]:
    paths: List[str] = []
    tolerances: Dict[str, float] = {}
    i = 0
    while i < len(argv):
        arg = argv[i]
        if arg == "--tol":
            i += 1
            if i >= len(argv):
                raise SystemExit(f"--tol needs a value\n{USAGE}")
            spec = argv[i]
            key, _, val = spec.rpartition("=")
            try:
                tolerances[key or "*"] = float(val)
            except ValueError:
                raise SystemExit(
                    f"--tol expects a number, got {spec!r}\n{USAGE}")
        else:
            paths.append(arg)
        i += 1
    if len(paths) != 2:
        raise SystemExit(USAGE)
    return paths[0], paths[1], tolerances


def main(argv: List[str]) -> int:
    path_a, path_b, tolerances = _parse_args(argv)
    with open(path_a) as f:
        a = json.load(f)
    with open(path_b) as f:
        b = json.load(f)
    warnings: List[str] = []
    drifts = diff_reports(a, b, tolerances, warnings=warnings)
    for w in warnings:
        print(f"WARN {w}")
    for d in drifts:
        print(d)
    n = sum(1 for sec in SECTIONS for _ in (a.get(sec) or {}))
    if drifts:
        print(f"# scenario-diff: {len(drifts)} metric(s) out of tolerance")
        return 1
    print(f"# scenario-diff: OK ({path_a} vs {path_b}, "
          f"{n} section groups compared)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
