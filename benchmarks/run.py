"""FDNInspector benchmark suite — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows for every experiment and a
summary of the paper-claim assertions. Roofline/dry-run results (the
pod-scale analyses) are summarized from results/*.json when present; run
``python -m benchmarks.roofline`` / ``python -m repro.launch.dryrun`` to
regenerate them (they need the 512-device XLA flag set at process start,
so they are separate entry points).
"""
from __future__ import annotations

import importlib
import json
import os
import sys
import time

BENCHES = [
    "fig5_platform_capability",
    "fig6_metric_detail",
    "fig7_function_heterogeneity",
    "fig8_cpu_interference",
    "fig9_memory_interference",
    "fig10_collaboration",
    "fig11_data_locality",
    "table4_energy",
    "policy_sweep",
    "bench_sched_throughput",
    "bench_metrics_ingest",
    "bench_chain_throughput",
    "bench_autoscale",
    "bench_streaming_replay",
    "bench_qos",
]


def scenario_main(args) -> int:
    """``python benchmarks/run.py scenario [name]``: run one registered
    FDNInspector scenario, validate its report schema, print the canonical
    JSON.  No name (or --list) lists the registry."""
    from repro.inspector import ScenarioReport, registry, run_scenario
    if not args or args[0] in ("-l", "--list"):
        for name in registry.names():
            print(name)
        return 0
    name = args[0]
    report = run_scenario(registry.get(name))
    payload = report.to_json()
    ScenarioReport.validate(json.loads(payload))
    print(payload)
    return 0


def scenario_diff_main(args) -> int:
    """``python benchmarks/run.py scenario-diff a.json b.json``: compare
    two canonical ScenarioReport JSONs with per-metric relative
    tolerances; exit 1 on drift (see benchmarks/scenario_diff.py)."""
    from benchmarks.scenario_diff import main as diff_main
    return diff_main(args)


def trace_main(args) -> int:
    """``python benchmarks/run.py trace <scenario> [--out PATH]
    [--sample S]``: run a registered scenario with the flight recorder
    attached, write a Chrome trace-event JSON (load it in Perfetto /
    chrome://tracing) and print the latency_breakdown section."""
    from repro.inspector import registry
    from repro.inspector.scenario import run_scenario_state
    from repro.obs import write_chrome_trace
    usage = "usage: trace <scenario> [--out PATH] [--sample S]"
    out_path, sample = None, 1.0
    names = []
    i = 0
    while i < len(args):
        if args[i] == "--out":
            i += 1
            out_path = args[i]
        elif args[i] == "--sample":
            i += 1
            sample = float(args[i])
        else:
            names.append(args[i])
        i += 1
    if len(names) != 1:
        print(usage)
        return 1
    if names[0] not in registry.names():
        print(f"unknown scenario {names[0]!r}; any registered scenario "
              f"works, and these arms come pre-traced:")
        for name in registry.names():
            if name.startswith("trace/"):
                print(f"  {name}")
        return 1
    sc = registry.get(names[0]).replace(trace=True, trace_sample=sample)
    report, cp, _sink = run_scenario_state(sc)
    if out_path is None:
        out_path = "trace_" + names[0].replace("/", "_") + ".json"
    n_events = write_chrome_trace(cp.recorder, out_path,
                                  alerts=report.alerts)
    print(f"# {n_events} trace events -> {out_path}")
    print(json.dumps(report.latency_breakdown, indent=2, sort_keys=True))
    return 0


def explain_main(args) -> int:
    """``python benchmarks/run.py explain <scenario> [--out PATH]
    [--journal PATH] [--whatif policy=NAME[,key=val...]]``: run a
    registered scenario with the decision journal attached, check the
    same-policy replay oracle, optionally re-score the journal under an
    alternate policy config, and print/write the provenance summary."""
    from repro.inspector import registry
    from repro.inspector.scenario import run_scenario_state
    from repro.obs import (WhatIfConfig, decision_provenance_section,
                           replay, whatif_section)
    usage = ("usage: explain <scenario> [--out PATH] [--journal PATH] "
             "[--whatif policy=NAME[,key=val...]]")
    out_path, journal_path, whatif = None, None, None
    names = []
    i = 0
    while i < len(args):
        if args[i] == "--out":
            i += 1
            out_path = args[i]
        elif args[i] == "--journal":
            i += 1
            journal_path = args[i]
        elif args[i] == "--whatif":
            i += 1
            whatif = WhatIfConfig.parse(args[i])
        else:
            names.append(args[i])
        i += 1
    if len(names) != 1:
        print(usage)
        return 1
    if names[0] not in registry.names():
        print(f"unknown scenario {names[0]!r}; any registered scenario "
              f"works, and these arms come pre-journaled:")
        for name in registry.names():
            if name.startswith("prov/"):
                print(f"  {name}")
        return 1
    sc = registry.get(names[0]).replace(provenance=True)
    report, cp, _sink = run_scenario_state(sc)
    journal = cp.journal
    payload = {"scenario": names[0],
               "decision_provenance": report.decision_provenance}
    if journal.n:
        base = replay(journal)
        oracle_ok = base.matches(journal)
        payload["replay_oracle"] = bool(oracle_ok)
        if not oracle_ok:
            print("# REPLAY ORACLE FAILED: same-policy replay diverged "
                  "from the journaled choices")
        if whatif is not None:
            alt = replay(journal, whatif)
            payload["whatif"] = whatif_section(journal, base, alt)
    else:
        payload["replay_oracle"] = True
    if journal_path is not None:
        journal.save(journal_path)
        print(f"# {journal.n} journal rows -> {journal_path}")
    text = json.dumps(payload, indent=2, sort_keys=True)
    if out_path is not None:
        with open(out_path, "w") as f:
            f.write(text + "\n")
        print(f"# explain report -> {out_path}")
    print(text)
    return 0 if payload["replay_oracle"] else 1


def _summarize_json(path: str, kind: str):
    if not os.path.exists(path):
        print(f"# {kind}: {path} not found — run the generator first")
        return
    with open(path) as f:
        data = json.load(f)
    if kind == "dryrun":
        ok = sum(1 for r in data if r.get("ok"))
        print(f"dryrun/cells_ok,{0.0:.1f},ok={ok}/{len(data)}")
        for r in data:
            print(f"dryrun/{r['arch']}/{r['shape']}/m{r['mesh']},"
                  f"{r['compile_s'] * 1e6:.1f},"
                  f"ok={int(r['ok'])};flops_dev={r['flops_per_dev']:.3e};"
                  f"coll_dev={r['coll_bytes_per_dev']:.3e}")
    else:
        for key, r in data.items():
            if not r.get("ok"):
                continue
            print(f"roofline/{key},{0.0:.1f},"
                  f"comp_s={r['compute_s']:.3e};mem_s={r['memory_s']:.3e};"
                  f"coll_s={r['collective_s']:.3e};dom={r['dominant']};"
                  f"useful={r['useful_ratio']:.3f}")


def main() -> int:
    if len(sys.argv) > 1 and sys.argv[1] == "scenario":
        return scenario_main(sys.argv[2:])
    if len(sys.argv) > 1 and sys.argv[1] == "scenario-diff":
        return scenario_diff_main(sys.argv[2:])
    if len(sys.argv) > 1 and sys.argv[1] == "trace":
        return trace_main(sys.argv[2:])
    if len(sys.argv) > 1 and sys.argv[1] == "explain":
        return explain_main(sys.argv[2:])
    t0 = time.time()
    all_failures = []
    print("name,us_per_call,derived")
    for name in BENCHES:
        mod = importlib.import_module(f"benchmarks.{name}")
        t = time.time()
        rows, failures = mod.run_bench()
        for r in rows:
            print(r.csv())
        status = "PASS" if not failures else "FAIL:" + "|".join(failures)
        print(f"{name}/_claims,{(time.time() - t) * 1e6:.1f},{status}")
        all_failures += [f"{name}: {f}" for f in failures]
    _summarize_json("results/dryrun.json", "dryrun")
    _summarize_json("results/roofline.json", "roofline")
    print(f"# total wall: {time.time() - t0:.1f}s")
    if all_failures:
        print("# PAPER-CLAIM FAILURES:")
        for f in all_failures:
            print("#  -", f)
        return 1
    print("# all paper-claim assertions PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
