"""Fig. 7: primes-python / sentiment-analysis / JSON-loads at 30 VUs on the
four non-edge platforms.

Runs through the FDNInspector scenario runner (``registry.fig7_cell``) —
each (function, platform) cell is a declarative Scenario.

Paper claims validated here:
  * primes-python (compute-bound) is much slower everywhere and the
    hpc-node-cluster handles it best;
  * google-cloud-cluster is disproportionately bad at primes-python
    ("inability of GCF to handle compute intensive functions");
  * for the lighter functions the platforms are comparatively close;
  * every platform serves fewer primes requests than JSON-loads requests.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from benchmarks.fdn_common import Row, check, scenario_row
from repro.inspector import registry, run_scenario

DURATION = 120.0
PLATFORMS = ("hpc-node-cluster", "old-hpc-node-cluster", "cloud-cluster",
             "google-cloud-cluster")
FUNCTIONS = ("primes-python", "sentiment-analysis", "JSON-loads")


def run_bench() -> Tuple[List[Row], List[str]]:
    rows: List[Row] = []
    failures: List[str] = []
    p90: Dict = {}
    rps: Dict = {}
    for fn_name in FUNCTIONS:
        for pname in PLATFORMS:
            rep = run_scenario(registry.fig7_cell(pname, fn_name, DURATION))
            stats = rep.per_platform[pname]
            rows.append(scenario_row(rep.scenario["name"], stats))
            p90[(fn_name, pname)] = stats["p90_s"]
            rps[(fn_name, pname)] = stats["rps"]

    check(p90[("primes-python", "hpc-node-cluster")] ==
          min(p90[("primes-python", p)] for p in PLATFORMS),
          "hpc should be fastest for primes", failures)
    check(p90[("primes-python", "google-cloud-cluster")] >=
          3.0 * p90[("primes-python", "hpc-node-cluster")],
          "gcf should be >=3x slower than hpc for primes", failures)
    light_spread = max(p90[("JSON-loads", p)] for p in PLATFORMS) / \
        max(min(p90[("JSON-loads", p)] for p in PLATFORMS), 1e-9)
    check(light_spread < 3.0,
          "JSON-loads should be comparatively uniform across platforms",
          failures)
    for p in PLATFORMS:
        check(rps[("primes-python", p)] < rps[("JSON-loads", p)],
              f"{p}: primes throughput must trail JSON-loads", failures)
    return rows, failures


if __name__ == "__main__":
    rows, failures = run_bench()
    for r in rows:
        print(r.csv())
    print("failures:", failures or "none")
