"""Fig. 8: image-processing, 40 VUs on old-hpc-node-cluster with background
CPU load in {0%, 50%, 100%}.

Paper claims validated here:
  * +50% CPU load: no performance change;
  * +100% CPU load: P90 roughly doubles (0.8 s -> 1.5 s in the paper) and
    throughput drops.
"""
from __future__ import annotations

from typing import List, Tuple

from benchmarks.fdn_common import (Row, build_fdn, check, result_row,
                                   run_on_platform)

DURATION = 120.0
PLATFORM = "old-hpc-node-cluster"


def run_bench() -> Tuple[List[Row], List[str]]:
    rows: List[Row] = []
    failures: List[str] = []
    stats = {}
    for bg in (0.0, 0.5, 1.0):
        cp, gw, fns = build_fdn(data_location=PLATFORM)
        cp.platforms[PLATFORM].bg_cpu = bg
        res = run_on_platform(cp, gw, fns["image-processing"], PLATFORM, 40,
                              DURATION, sleep_s=0.5)
        rows.append(result_row(f"fig8/image-processing/bg_cpu{int(bg*100)}",
                               res, DURATION))
        stats[bg] = (res.p90_response(), res.requests_per_s(DURATION))

    check(stats[0.5][0] < 1.25 * stats[0.0][0],
          "50% CPU load should not hurt P90", failures)
    check(stats[1.0][0] > 1.5 * stats[0.0][0],
          "100% CPU load should inflate P90 >=1.5x", failures)
    check(stats[1.0][1] < stats[0.0][1],
          "100% CPU load should reduce throughput", failures)
    return rows, failures


if __name__ == "__main__":
    rows, failures = run_bench()
    for r in rows:
        print(r.csv())
    print("failures:", failures or "none")
