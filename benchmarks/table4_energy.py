"""Table 4: JSON-loads at a fixed open-loop arrival rate on edge-cluster vs
hpc-node-cluster for 600 s (the paper's 40-VU / 400-per-unit-time load).

Runs through the FDNInspector scenario runner (``registry.table4_cell``) —
energy comes straight from the ScenarioReport's per-platform section.

Paper claims validated here:
  * both platforms serve (essentially) the whole offered load;
  * both meet the 7 s P90 SLO;
  * edge total CPU energy is an order of magnitude below hpc
    (paper: 2 647 J vs 44 646 J = 16.9x; we assert >= 8x and report the
    measured ratio).
"""
from __future__ import annotations

from typing import List, Tuple

from benchmarks.fdn_common import Row, check, scenario_row
from repro.inspector import registry, run_scenario

DURATION = 600.0
RPS = 40.0          # the paper's 400 requests per 10 s sampling window


def run_bench() -> Tuple[List[Row], List[str]]:
    rows: List[Row] = []
    failures: List[str] = []
    energy = {}
    stats = {}
    for pname in ("edge-cluster", "hpc-node-cluster"):
        rep = run_scenario(registry.table4_cell(pname, DURATION, RPS))
        s = rep.per_platform[pname]
        energy[pname] = s["energy_j"]
        stats[pname] = s
        rows.append(scenario_row(rep.scenario["name"], s,
                                 extra=f"joules={s['energy_j']:.0f}"))

    ratio = energy["hpc-node-cluster"] / max(energy["edge-cluster"], 1e-9)
    rows.append(Row("table4/energy_ratio", 0.0,
                    f"hpc_J={energy['hpc-node-cluster']:.0f};"
                    f"edge_J={energy['edge-cluster']:.0f};"
                    f"ratio={ratio:.1f}x;paper=16.9x"))

    for pname, s in stats.items():
        check(s["completed"] >= 0.98 * RPS * DURATION,
              f"{pname} should serve ~the whole load "
              f"(got {s['completed']})", failures)
        check(s["p90_s"] <= 7.0,
              f"{pname} should meet the 7 s P90 SLO", failures)
    check(ratio >= 8.0,
          f"energy ratio should be >=8x (measured {ratio:.1f}x)", failures)
    return rows, failures


if __name__ == "__main__":
    rows, failures = run_bench()
    for r in rows:
        print(r.csv())
    print("failures:", failures or "none")
