"""Fig. 9: image-processing, 40 VUs on old-hpc-node-cluster with background
MEMORY load in {0%, 50%, 100%}.

Runs through the FDNInspector scenario runner (``registry.fig9_cell``,
``Scenario.bg_mem`` carries the interference knob) instead of a hand-wired
control plane; stats come from each cell's ``ScenarioReport``.

Paper claims validated here:
  * +50% memory load: no performance change (free memory still available
    for replicas);
  * +100% memory load: P90 degrades dramatically (0.8 s -> ~6 s, ~7x).
"""
from __future__ import annotations

from typing import List, Tuple

from benchmarks.fdn_common import Row, check, scenario_row
from repro.inspector import registry, run_scenario

PLATFORM = "old-hpc-node-cluster"


def run_bench() -> Tuple[List[Row], List[str]]:
    rows: List[Row] = []
    failures: List[str] = []
    stats = {}
    for bg in (0.0, 0.5, 1.0):
        rep = run_scenario(registry.fig9_cell(bg))
        cell = rep.per_platform[PLATFORM]
        rows.append(scenario_row(rep.scenario["name"], cell))
        stats[bg] = (cell["p90_s"], cell["rps"])

    check(stats[0.5][0] < 1.25 * stats[0.0][0],
          "50% memory load should not hurt P90", failures)
    check(stats[1.0][0] > 4.0 * stats[0.0][0],
          "100% memory load should inflate P90 >=4x (swap cliff)", failures)
    return rows, failures


if __name__ == "__main__":
    rows, failures = run_bench()
    for r in rows:
        print(r.csv())
    print("failures:", failures or "none")
