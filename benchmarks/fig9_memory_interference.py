"""Fig. 9: image-processing, 40 VUs on old-hpc-node-cluster with background
MEMORY load in {0%, 50%, 100%}.

Paper claims validated here:
  * +50% memory load: no performance change (free memory still available
    for replicas);
  * +100% memory load: P90 degrades dramatically (0.8 s -> ~6 s, ~7x).
"""
from __future__ import annotations

from typing import List, Tuple

from benchmarks.fdn_common import (Row, build_fdn, check, result_row,
                                   run_on_platform)

DURATION = 120.0
PLATFORM = "old-hpc-node-cluster"


def run_bench() -> Tuple[List[Row], List[str]]:
    rows: List[Row] = []
    failures: List[str] = []
    stats = {}
    for bg in (0.0, 0.5, 1.0):
        cp, gw, fns = build_fdn(data_location=PLATFORM)
        cp.platforms[PLATFORM].bg_mem = bg
        res = run_on_platform(cp, gw, fns["image-processing"], PLATFORM, 40,
                              DURATION, sleep_s=0.5)
        rows.append(result_row(f"fig9/image-processing/bg_mem{int(bg*100)}",
                               res, DURATION))
        stats[bg] = (res.p90_response(), res.requests_per_s(DURATION))

    check(stats[0.5][0] < 1.25 * stats[0.0][0],
          "50% memory load should not hurt P90", failures)
    check(stats[1.0][0] > 4.0 * stats[0.0][0],
          "100% memory load should inflate P90 >=4x (swap cliff)", failures)
    return rows, failures


if __name__ == "__main__":
    rows, failures = run_bench()
    for r in rows:
        print(r.csv())
    print("failures:", failures or "none")
