"""Scheduler-admission throughput: per-invocation vs batched vs the
fused jit admission fast path.

The FDN's control plane routes every invocation through a policy decision
(paper §3.1.3).  This benchmark measures decisions/sec of the admission
paths on the five Table-3 platforms with the production
``SLOCompositePolicy``:

  * ``per_invocation`` — ``FDNControlPlane.submit`` in a loop: one
    platform scan + policy evaluation + queue drain per invocation (the
    paper-scale path: 5 platforms x 50 VUs);
  * ``batched`` — ``FDNControlPlane.submit_batch``, PR-1 default config
    (knowledge-base decision rows retained);
  * ``pr1_hedged`` — the PR-1 batched admission under the paper's
    production fault-tolerance config (hedging armed): full-matrix
    ``Policy.score`` over (N, P), per-invocation KB decision rows, and
    one hedge ``watch`` registration (alternates list + timer event) per
    invocation — a faithful re-implementation of the PR-1 loop on
    today's substrate (the substrate underneath is *faster* than PR-1's,
    so the measured speedup is conservative);
  * ``jit_hedged`` — the fused admission path under the same config:
    one jitted filter-cascade + argmin decision per distinct function
    (``repro.kernels.policy_score``), bulk KB counters, and ONE
    vectorized hedge timer per (fn, platform) admission group;
  * ``columnar`` — ``InvocationBatch`` struct-of-arrays admission:
    arrivals live as NumPy columns end to end, ``submit_batch`` takes
    zero-copy chunk views of one preallocated stream, and ``Invocation``
    objects materialize lazily only when a replica starts a row (the
    streaming-replay configuration: no KB decision rows);
  * ``columnar_traced`` — the columnar arm with the flight recorder
    attached at 1/16 head-based sampling (repro.obs): the tracing-
    overhead gate, pinned <= 15% below the untraced columnar rate;
  * ``columnar_qos`` — the columnar arm with the QoS layer armed
    (three classes + tenants on every row, non-uniform DRR weights, the
    admission gate in the path): the QoS-overhead gate, pinned <= 15%
    below the plain columnar rate;
  * ``columnar_provenance`` — the columnar arm with the decision
    journal attached (repro.obs.provenance): every fused decision
    records its kill bits, score columns, choice and runner-up margin;
    the provenance-overhead gate, pinned <= 15% below the plain
    columnar rate.

No simulated time elapses while submitting, so all arms schedule against
identical platform-state snapshots at t=0 and the measurement isolates
the admission engine.  Claims checked:

  * ``batched`` sustains >= 10x ``per_invocation`` (>= 3x in --smoke);
  * ``jit_hedged`` sustains >= 3x ``pr1_hedged`` at 5 platforms x 10^4
    invocations (the compiled-admission acceptance pin);
  * ``columnar`` sustains >= 2x ``batched`` (the array-native-core
    acceptance pin: the next jump past the PR-4 729k/s floor);
  * jax and NumPy score backends pick identical platforms.

Measurements always land in ``BENCH_sched.json`` (``--json PATH``
overrides the location; CI uploads it); ``--check-floor FLOOR.json`` fails when
any pinned metric drops more than 30% below its floor
(``benchmarks/perf_floor.json`` — re-bless it alongside intentional
hot-path changes).
"""
from __future__ import annotations

import gc
import json
import sys
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks.fdn_common import Row, build_fdn, check
from repro.core import scheduler as sched
from repro.core.faults import HedgePolicy
from repro.core.invocation_batch import InvocationBatch
from repro.core.scheduler import SLOCompositePolicy
from repro.core.types import Invocation

FULL_N = 40_000
SMOKE_N = 4_000
HEDGE_FULL_N = 10_000        # the acceptance pin's 5 platforms x 10^4
BATCH = 2_048
FLOOR_GRACE = 0.30           # fail when > 30% below the pinned floor
FN_MIX = ("nodeinfo", "primes-python", "JSON-loads", "image-processing")


class PR1CompositePolicy(SLOCompositePolicy):
    """SLOCompositePolicy pinned to the PR-1 decision path: no fused
    per-function decisions, so ``choose_batch`` scores the full (N, P)
    matrix and row-argmins it."""

    def fn_decisions(self, fns, snap, n=None):
        return None


def _make_invs(fns, n: int) -> List[Invocation]:
    specs = [fns[name] for name in FN_MIX]
    return [Invocation(specs[i % len(specs)], 0.0) for i in range(n)]


def _make_stream(fns, n: int, qos: bool = False) -> InvocationBatch:
    """The same round-robin mix as ``_make_invs``, born columnar.  With
    ``qos`` every row carries a class (cycling through all three) and a
    tenant, so the QoS arm pays the full column cost."""
    specs = [fns[name] for name in FN_MIX]
    idx = np.arange(n, dtype=np.int32)
    kw = {}
    if qos:
        kw = {"qos": (idx % 3).astype(np.int8),
              "tenant": (idx % 7).astype(np.int32)}
    return InvocationBatch(specs, idx % len(specs), np.zeros(n), **kw)


def _seed_observations(cp, fns, per_pair: int = 12):
    """>= 10 latency observations per (fn, platform): the hedge policy
    only arms timers once the P90 model has real samples."""
    for name in FN_MIX:
        for pname in cp.platforms:
            for _ in range(per_pair):
                inv = Invocation(fns[name], 0.0)
                inv.platform = pname
                inv.exec_time = 0.05
                inv.end_t = 0.05
                cp.perf.observe(inv)


def _run_arm(kind: str, n: int) -> Tuple[float, int, int]:
    """Returns (seconds, accepted, n)."""
    cp, _gw, fns = build_fdn(analytic=True)
    if kind == "pr1_hedged":
        cp.policy = PR1CompositePolicy(cp.perf, cp.placement)
        _seed_observations(cp, fns)
        hedge = HedgePolicy(cp.clock, cp.perf, enabled=True)
    elif kind == "jit_hedged":
        cp.hedge.enabled = True
        cp.kb.log_decisions = False
        sched.set_score_backend("jax")
        _seed_observations(cp, fns)
    elif kind == "columnar":
        cp.kb.log_decisions = False
    elif kind == "columnar_traced":
        from repro.obs import FlightRecorder
        cp.kb.log_decisions = False
        cp.attach_recorder(FlightRecorder(sample=1.0 / 16))
    elif kind == "columnar_qos":
        from repro.core.qos import QosSpec
        cp.kb.log_decisions = False
        # DRR queues + admission gate armed; no limits or thresholds,
        # so every row is still accepted and the arms stay comparable
        cp.attach_qos(QosSpec(weights=(4, 2, 1)))
    elif kind == "columnar_provenance":
        from repro.obs import DecisionJournal
        cp.kb.log_decisions = False
        cp.attach_provenance(DecisionJournal())
    if kind in ("columnar", "columnar_traced", "columnar_qos",
                "columnar_provenance"):
        stream = _make_stream(fns, n, qos=kind == "columnar_qos")
    else:
        invs = _make_invs(fns, n)

    # the previous arm's control plane (queues, timer closures) is garbage
    # by now; collect it OUTSIDE the timed region so each arm pays for its
    # own allocation behavior only (GC stays ON — collector pressure from
    # per-invocation timer closures is a real cost of that design)
    gc.collect()
    t0 = time.perf_counter()
    if kind == "per_invocation":
        accepted = sum(1 for inv in invs if cp.submit(inv))
    elif kind in ("batched", "jit_hedged"):
        accepted = 0
        for lo in range(0, n, BATCH):
            accepted += cp.submit_batch(invs[lo:lo + BATCH])
    elif kind in ("columnar", "columnar_traced", "columnar_qos",
                  "columnar_provenance"):
        accepted = 0
        for lo in range(0, n, BATCH):
            accepted += cp.submit_batch(stream.view(lo,
                                                    min(lo + BATCH, n)))
    elif kind == "pr1_hedged":
        accepted = 0
        admit = {name: sc.admit for name, sc in cp.sidecars.items()}
        for lo in range(0, n, BATCH):
            batch = invs[lo:lo + BATCH]
            accepted += cp.submit_batch(batch)
            # PR-1's hedging block: alternates + watch per invocation
            alive = cp.alive_platforms()
            for inv in batch:
                if inv.platform is None:
                    continue
                target = cp.platforms[inv.platform]
                alternates = [p for p in alive if p is not target]
                hedge.watch(inv, target, alternates,
                            lambda i, p: admit[p.prof.name](i))
    else:
        raise ValueError(kind)
    dt = time.perf_counter() - t0
    sched.set_score_backend("auto")
    return dt, accepted, n


def _check_backend_parity(failures: List[str]):
    """jax and NumPy cascades must pick identical platforms."""
    cp, _gw, fns = build_fdn(analytic=True)
    _seed_observations(cp, fns)
    invs = _make_invs(fns, 512)
    plats = list(cp.platforms.values())
    picks = {}
    for backend in ("numpy", "jax"):
        sched.set_score_backend(backend)
        picks[backend] = [p.prof.name if p else None for p in
                          cp.policy.choose_batch(invs, plats)]
    sched.set_score_backend("auto")
    check(picks["numpy"] == picks["jax"],
          "jax score backend must pick byte-identical platforms to the "
          "NumPy oracle", failures)


def _warmup():
    """Absorb one-time costs (jax import, jit traces) outside timing."""
    sched.set_score_backend("jax")
    cp, _gw, fns = build_fdn(analytic=True)
    cp.submit_batch(_make_invs(fns, 128))
    sched.set_score_backend("auto")


def _planned_stages_per_s(smoke: bool) -> float:
    from benchmarks.bench_chain_throughput import (SMOKE_PLANS,
                                                   _bench_planner)
    _fresh, shared, _stages = _bench_planner(SMOKE_PLANS if smoke
                                             else 1_000)
    return shared


def check_floor(results: Dict, floor_path: str,
                failures: List[str]) -> None:
    with open(floor_path) as f:
        floors = json.load(f)
    for name, floor in floors.get("decisions_per_s", {}).items():
        got = results["decisions_per_s"].get(name)
        limit = floor * (1.0 - FLOOR_GRACE)
        check(got is not None and got >= limit,
              f"perf floor breach: decisions_per_s[{name}] = "
              f"{got if got is None else round(got)} < {limit:.0f} "
              f"(floor {floor:.0f} - {FLOOR_GRACE:.0%})", failures)
    floor = floors.get("planned_stages_per_s")
    if floor is not None:
        got = results["planned_stages_per_s"]
        limit = floor * (1.0 - FLOOR_GRACE)
        check(got >= limit,
              f"perf floor breach: planned_stages_per_s = {got:.0f} < "
              f"{limit:.0f} (floor {floor:.0f} - {FLOOR_GRACE:.0%})",
              failures)


def run_bench(smoke: bool = False,
              results_out: Optional[Dict] = None
              ) -> Tuple[List[Row], List[str]]:
    n = SMOKE_N if smoke else FULL_N
    # the hedged arms always run the acceptance pin's 10^4 invocations:
    # they are cheap, and the per-invocation-timer arm's cost profile
    # (and so the measured speedup) only stabilizes at full batch count
    hedge_n = HEDGE_FULL_N
    rows: List[Row] = []
    failures: List[str] = []
    _warmup()

    rates: Dict[str, float] = {}
    reps = 2 if smoke else 3                   # best-of: tame CI jitter
    for kind, kn in (("per_invocation", n), ("batched", n),
                     ("columnar", n), ("columnar_traced", n),
                     ("columnar_qos", n), ("columnar_provenance", n),
                     ("pr1_hedged", hedge_n), ("jit_hedged", hedge_n)):
        dt = float("inf")
        for _ in range(reps):
            rep_dt, acc, kn = _run_arm(kind, kn)
            dt = min(dt, rep_dt)
            check(acc == kn, f"{kind} should accept every invocation "
                  f"(got {acc}/{kn})", failures)
        rates[kind] = kn / max(dt, 1e-9)
        rows.append(Row(f"sched_throughput/{kind}", dt / kn * 1e6,
                        f"decisions_per_s={rates[kind]:.0f};"
                        f"accepted={acc}/{kn};best_of={reps}"))

    speedup = rates["batched"] / max(rates["per_invocation"], 1e-9)
    hedged_speedup = rates["jit_hedged"] / max(rates["pr1_hedged"], 1e-9)
    columnar_speedup = rates["columnar"] / max(rates["batched"], 1e-9)
    traced_frac = rates["columnar_traced"] / max(rates["columnar"], 1e-9)
    qos_frac = rates["columnar_qos"] / max(rates["columnar"], 1e-9)
    prov_frac = (rates["columnar_provenance"]
                 / max(rates["columnar"], 1e-9))
    rows.append(Row("sched_throughput/speedups", 0.0,
                    f"batched_vs_per_invocation={speedup:.1f}x;"
                    f"jit_hedged_vs_pr1_hedged={hedged_speedup:.1f}x;"
                    f"columnar_vs_batched={columnar_speedup:.1f}x;"
                    f"traced_vs_columnar={traced_frac:.2f}x;"
                    f"qos_vs_columnar={qos_frac:.2f}x;"
                    f"provenance_vs_columnar={prov_frac:.2f}x;"
                    f"batch={BATCH}"))

    target = 3.0 if smoke else 10.0
    check(speedup >= target,
          f"submit_batch should be >= {target:.0f}x per-invocation submit "
          f"(got {speedup:.1f}x)", failures)
    check(hedged_speedup >= 3.0,
          "fused jit admission (grouped hedging) should be >= 3x the "
          f"PR-1 batched path (got {hedged_speedup:.1f}x)", failures)
    check(columnar_speedup >= 2.0,
          "struct-of-arrays admission should be >= 2x the object-list "
          f"batched path (got {columnar_speedup:.1f}x)", failures)
    check(traced_frac >= 0.85,
          "sampled tracing (1/16) should cost <= 15% of the columnar "
          f"admission rate (got {traced_frac:.2f}x)", failures)
    # at smoke scale (~3 ms per timed rep) the ratio is jitter-dominated;
    # the 15% pin is enforced at full scale, where the per-drain DRR cost
    # amortizes (measured ~0.9-1.0x), and absolutely via the pinned
    # columnar_qos decisions/s floor
    qos_target = 0.70 if smoke else 0.85
    check(qos_frac >= qos_target,
          f"QoS classes + DRR + admission gate should cost <= "
          f"{(1.0 - qos_target):.0%} of the columnar admission rate "
          f"(got {qos_frac:.2f}x)", failures)
    # same smoke-jitter caveat as the QoS gate: the 15% provenance pin
    # is enforced at full scale and absolutely via the pinned
    # columnar_provenance decisions/s floor
    prov_target = 0.70 if smoke else 0.85
    check(prov_frac >= prov_target,
          f"decision-journal recording should cost <= "
          f"{(1.0 - prov_target):.0%} of the columnar admission rate "
          f"(got {prov_frac:.2f}x)", failures)
    _check_backend_parity(failures)

    if results_out is not None:
        results_out.update({
            "n": n, "hedge_n": hedge_n, "batch": BATCH, "smoke": smoke,
            "decisions_per_s": {k: round(v, 1) for k, v in rates.items()},
            "speedups": {"batched_vs_per_invocation": round(speedup, 2),
                         "jit_hedged_vs_pr1_hedged":
                         round(hedged_speedup, 2),
                         "columnar_vs_batched":
                         round(columnar_speedup, 2),
                         "traced_vs_columnar": round(traced_frac, 3),
                         "qos_vs_columnar": round(qos_frac, 3),
                         "provenance_vs_columnar": round(prov_frac, 3)},
            "tracing_overhead_pct": round((1.0 - traced_frac) * 100.0, 1),
            "provenance_overhead_pct":
            round((1.0 - prov_frac) * 100.0, 1),
            "planned_stages_per_s":
            round(_planned_stages_per_s(smoke), 1),
        })
    return rows, failures


def main(argv: List[str]) -> int:
    smoke = "--smoke" in argv
    floor_path = None
    json_path = "BENCH_sched.json"       # always emitted; --json overrides
    if "--json" in argv:
        json_path = argv[argv.index("--json") + 1]
    if "--check-floor" in argv:
        floor_path = argv[argv.index("--check-floor") + 1]
    results: Dict = {}
    rows, failures = run_bench(smoke=smoke, results_out=results)
    if floor_path is not None:
        check_floor(results, floor_path, failures)
    if json_path is not None:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")
    for r in rows:
        print(r.csv())
    print("failures:", failures or "none")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
