"""Scheduler-decision throughput: batched vs per-invocation submit.

The FDN's control plane routes every invocation through a policy decision
(paper §3.1.3).  This benchmark measures raw decisions/sec of the two
admission paths on the five Table-3 platforms with the production
``SLOCompositePolicy``:

  * per-invocation: ``FDNControlPlane.submit`` in a loop — one platform
    scan + policy evaluation + queue drain per invocation (the paper-scale
    path: 5 platforms x 50 VUs);
  * batched: ``FDNControlPlane.submit_batch`` over the same invocations —
    one columnar platform snapshot + one vectorized ``Policy.score`` per
    batch, bulk knowledge-base logging, one queue drain per platform per
    batch.

No simulated time elapses while submitting, so both arms schedule against
identical platform-state snapshots at t=0 and the measurement isolates the
decision engine.  Claim checked: the batched path sustains >= 10x the
per-invocation decision throughput (>= 3x in --smoke, which is sized for
CI noise).
"""
from __future__ import annotations

import sys
import time
from typing import List, Tuple

from benchmarks.fdn_common import Row, build_fdn, check
from repro.core.types import Invocation

FULL_N = 40_000
SMOKE_N = 4_000
BATCH = 2_048
FN_MIX = ("nodeinfo", "primes-python", "JSON-loads", "image-processing")


def _make_invs(fns, n: int) -> List[Invocation]:
    specs = [fns[name] for name in FN_MIX]
    return [Invocation(specs[i % len(specs)], 0.0) for i in range(n)]


def _run_arm(batched: bool, n: int) -> Tuple[float, int, int]:
    """Returns (seconds, accepted, n)."""
    cp, _gw, fns = build_fdn(analytic=True)
    invs = _make_invs(fns, n)
    t0 = time.perf_counter()
    if batched:
        accepted = 0
        for lo in range(0, n, BATCH):
            accepted += cp.submit_batch(invs[lo:lo + BATCH])
    else:
        accepted = sum(1 for inv in invs if cp.submit(inv))
    return time.perf_counter() - t0, accepted, n


def run_bench(smoke: bool = False) -> Tuple[List[Row], List[str]]:
    n = SMOKE_N if smoke else FULL_N
    rows: List[Row] = []
    failures: List[str] = []

    t_seq, acc_seq, _ = _run_arm(batched=False, n=n)
    t_bat, acc_bat, _ = _run_arm(batched=True, n=n)
    seq_rate = n / max(t_seq, 1e-9)
    bat_rate = n / max(t_bat, 1e-9)
    speedup = bat_rate / max(seq_rate, 1e-9)

    rows.append(Row("sched_throughput/per_invocation", t_seq / n * 1e6,
                    f"decisions_per_s={seq_rate:.0f};accepted={acc_seq}/{n}"))
    rows.append(Row("sched_throughput/batched", t_bat / n * 1e6,
                    f"decisions_per_s={bat_rate:.0f};accepted={acc_bat}/{n};"
                    f"batch={BATCH};speedup={speedup:.1f}x"))

    check(acc_seq == n, "per-invocation path should accept every "
          f"invocation (got {acc_seq}/{n})", failures)
    check(acc_bat == n, "batched path should accept every invocation "
          f"(got {acc_bat}/{n})", failures)
    target = 3.0 if smoke else 10.0
    check(speedup >= target,
          f"submit_batch should be >= {target:.0f}x per-invocation submit "
          f"(got {speedup:.1f}x)", failures)
    return rows, failures


def main(argv: List[str]) -> int:
    smoke = "--smoke" in argv
    rows, failures = run_bench(smoke=smoke)
    for r in rows:
        print(r.csv())
    print("failures:", failures or "none")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
