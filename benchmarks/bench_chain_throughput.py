"""Chain-planner throughput + the split-vs-colocate A/B claims.

Arm 1 — planner throughput: the data-gravity planner places whole chains
against the five Table-3 platforms with the production
``SLOCompositePolicy``.  Measured per *stage* (the unit a per-invocation
scheduler would decide): one ``Policy.score`` call per plan covers every
stage, so a plan costs array ops, not S x P platform scans.  Two
sub-arms: a fresh ``PlatformSnapshot`` per plan (the cold path) and a
shared snapshot across a batch of plans (the ``submit_batch``-style fast
path).  Claim: the shared-snapshot planner places >= 10^4 stages/s.

Arm 2 — collaborative execution vs forced co-location: the registered
``chains/split-vs-colocate-ab`` scenarios must show the flip the paper's
§3.1.3/§5.1.4 predict — with a fast interconnect the split arm beats the
co-located arm on end-to-end chain p90 (queue relief outweighs cheap
transfers); with a slow WAN the order reverses (features crossing
platforms dominate).  Reports are seed-deterministic (byte-identical
JSON across runs).
"""
from __future__ import annotations

import json
import sys
import time
from typing import Dict, List, Optional, Tuple

from benchmarks.fdn_common import Row, build_fdn, check
from repro.chains import DataGravityPlanner, catalog
from repro.core.scheduler import PlatformSnapshot

FULL_PLANS = 3_000
SMOKE_PLANS = 400


def _build_harness():
    cp, _gw, fns = build_fdn(analytic=True)
    tmpl = catalog.get("etl-pipeline")
    allfns = dict(fns)
    allfns.update(tmpl.functions)
    for spec in tmpl.functions.values():
        for p in cp.platforms.values():
            p.deploy(spec)
    for inp in tmpl.inputs:
        cp.placement.stores["cloud-cluster"].put(inp.key, inp.size_bytes)
    planner = DataGravityPlanner(cp.policy, cp.placement, allfns)
    return cp, planner, tmpl


def _bench_planner(n_plans: int) -> Tuple[float, float, int]:
    """Returns (fresh_stages_per_s, shared_stages_per_s, stages)."""
    cp, planner, tmpl = _build_harness()
    plats = list(cp.platforms.values())
    stages = tmpl.chain.n_stages

    t0 = time.perf_counter()
    for _ in range(n_plans):
        planner.plan(tmpl.chain, plats, mode="auto")
    fresh = n_plans * stages / max(time.perf_counter() - t0, 1e-9)

    snap = PlatformSnapshot(plats)
    t0 = time.perf_counter()
    for _ in range(n_plans):
        planner.plan(tmpl.chain, snap, mode="auto")
    shared = n_plans * stages / max(time.perf_counter() - t0, 1e-9)
    return fresh, shared, n_plans * stages


def _run_ab(smoke: bool):
    from repro.inspector import registry, run_scenario
    from repro.inspector.registry import split_vs_colocate
    if smoke:
        fast = run_scenario(split_vs_colocate(2e9, duration_s=40.0))
        slow = run_scenario(split_vs_colocate(3e6, rps=1.0,
                                              duration_s=40.0,
                                              suffix="-slowwan"))
    else:
        fast = run_scenario(registry.get("chains/split-vs-colocate-ab"))
        slow = run_scenario(
            registry.get("chains/split-vs-colocate-ab-slowwan"))
    return fast, slow


def run_bench(smoke: bool = False,
              results_out: Optional[Dict] = None
              ) -> Tuple[List[Row], List[str]]:
    rows: List[Row] = []
    failures: List[str] = []

    n = SMOKE_PLANS if smoke else FULL_PLANS
    fresh, shared, stages = _bench_planner(n)
    stages_per_plan = stages // n
    rows.append(Row("chain_throughput/plan_fresh_snapshot",
                    1e6 * stages_per_plan / max(fresh, 1e-9),
                    f"stages_per_s={fresh:.0f};plans={n}"))
    rows.append(Row("chain_throughput/plan_shared_snapshot",
                    1e6 * stages_per_plan / max(shared, 1e-9),
                    f"stages_per_s={shared:.0f};plans={n}"))
    target = 2.5e3 if smoke else 1e4
    check(shared >= target,
          f"shared-snapshot planner should place >= {target:.0f} "
          f"stages/s on 5 platforms (got {shared:.0f})", failures)

    fast, slow = _run_ab(smoke)
    f_split = fast.per_chain["ab@split"]["p90_s"]
    f_coloc = fast.per_chain["ab@colocate"]["p90_s"]
    s_split = slow.per_chain["ab@split"]["p90_s"]
    s_coloc = slow.per_chain["ab@colocate"]["p90_s"]
    rows.append(Row("chain_ab/fast_wan", f_split * 1e6,
                    f"split_p90={f_split:.3f};colocate_p90={f_coloc:.3f};"
                    f"completed={fast.per_chain['ab@split']['completed']}"))
    rows.append(Row("chain_ab/slow_wan", s_split * 1e6,
                    f"split_p90={s_split:.3f};colocate_p90={s_coloc:.3f};"
                    f"completed={slow.per_chain['ab@split']['completed']}"))
    check(f_split < f_coloc,
          "fast WAN: collaborative split should beat forced co-location "
          f"on chain p90 (split={f_split:.3f} vs coloc={f_coloc:.3f})",
          failures)
    check(s_split > s_coloc,
          "slow WAN: forced co-location should beat the gravity-blind "
          f"split on chain p90 (split={s_split:.3f} vs "
          f"coloc={s_coloc:.3f})", failures)

    if results_out is not None:
        results_out.update({
            "smoke": smoke, "plans": n, "stages": stages,
            "stages_per_s": {
                "fresh_snapshot": round(fresh, 1),
                "shared_snapshot": round(shared, 1),
            },
            "ab": {
                "fast_wan": {"split_p90_s": f_split,
                             "colocate_p90_s": f_coloc},
                "slow_wan": {"split_p90_s": s_split,
                             "colocate_p90_s": s_coloc},
            },
        })
    return rows, failures


def main(argv: List[str]) -> int:
    smoke = "--smoke" in argv
    json_path = "BENCH_chain.json"       # always emitted; --json overrides
    if "--json" in argv:
        json_path = argv[argv.index("--json") + 1]
    results: Dict = {}
    rows, failures = run_bench(smoke=smoke, results_out=results)
    with open(json_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    for r in rows:
        print(r.csv())
    print("failures:", failures or "none")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
