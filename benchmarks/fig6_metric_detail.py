"""Fig. 6: all nine Table-1 metrics for nodeinfo at 20 VUs on every
platform.

Runs through the FDNInspector scenario runner (``registry.fig6_cell``)
instead of a hand-wired control plane; the per-run stats come from the
``ScenarioReport`` and the metric *series* (cold-start timing, replica
ramp, infra visibility) from the control plane behind it
(``run_scenario_state``).

Paper claims validated here:
  * cold starts happen early, then stop once containers are warm;
  * replica counts ramp up under load;
  * the OpenFaaS edge platform exposes no cold-start metric (external
    instrumentation needed) and google-cloud exposes no infra metrics.
"""
from __future__ import annotations

from typing import List, Tuple

from benchmarks.fdn_common import Row, check, scenario_row
from repro.inspector import registry
from repro.inspector.scenario import run_scenario_state

DURATION = 120.0
PLATFORMS = ("hpc-node-cluster", "old-hpc-node-cluster", "cloud-cluster",
             "google-cloud-cluster", "edge-cluster")


def run_bench() -> Tuple[List[Row], List[str]]:
    rows: List[Row] = []
    failures: List[str] = []
    for pname in PLATFORMS:
        rep, cp, _sink = run_scenario_state(
            registry.fig6_cell(pname, DURATION))
        stats = rep.per_platform[pname]
        m = cp.metrics
        cold = m.series(pname, "nodeinfo", "cold_starts", "sum")
        reqs = m.series(pname, "nodeinfo", "requests", "count")
        replicas = m.series(pname, "nodeinfo", "replicas", "mean")
        infra = m.series(pname, "_infra", "cpu_util", "mean")
        extra = (f"cold_total={sum(v for _, v in cold):.0f};"
                 f"windows={len(reqs)};"
                 f"max_replicas={max((v for _, v in replicas), default=0):.0f};"
                 f"infra_visible={int(bool(infra))}")
        rows.append(scenario_row(rep.scenario["name"], stats, extra))

        if cold:
            t_half = DURATION / 2
            late = sum(v for t, v in cold if t > t_half)
            early = sum(v for t, v in cold if t <= t_half)
            check(late <= early,
                  f"{pname}: cold starts should concentrate early", failures)
        if pname == "google-cloud-cluster":
            check(not infra, "gcf infra metrics must be unavailable",
                  failures)
        else:
            check(bool(infra), f"{pname} infra metrics must be visible",
                  failures)
        check(stats["completed"] > 0, f"{pname} served nothing", failures)
    return rows, failures


if __name__ == "__main__":
    rows, failures = run_bench()
    for r in rows:
        print(r.csv())
    print("failures:", failures or "none")
