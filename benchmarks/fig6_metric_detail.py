"""Fig. 6: all nine Table-1 metrics for nodeinfo at 20 VUs on every
platform.

Paper claims validated here:
  * cold starts happen early, then stop once containers are warm;
  * replica counts ramp up under load;
  * the OpenFaaS edge platform exposes no cold-start metric (external
    instrumentation needed) and google-cloud exposes no infra metrics.
"""
from __future__ import annotations

from typing import List, Tuple

from benchmarks.fdn_common import (Row, build_fdn, check, result_row,
                                   run_on_platform)

DURATION = 120.0
PLATFORMS = ("hpc-node-cluster", "old-hpc-node-cluster", "cloud-cluster",
             "google-cloud-cluster", "edge-cluster")


def run_bench() -> Tuple[List[Row], List[str]]:
    rows: List[Row] = []
    failures: List[str] = []
    for pname in PLATFORMS:
        cp, gw, fns = build_fdn()
        res = run_on_platform(cp, gw, fns["nodeinfo"], pname, 20, DURATION)
        m = cp.metrics
        cold = m.series(pname, "nodeinfo", "cold_starts", "sum")
        reqs = m.series(pname, "nodeinfo", "requests", "count")
        replicas = m.series(pname, "nodeinfo", "replicas", "mean")
        infra = m.series(pname, "_infra", "cpu_util", "mean")
        extra = (f"cold_total={sum(v for _, v in cold):.0f};"
                 f"windows={len(reqs)};"
                 f"max_replicas={max((v for _, v in replicas), default=0):.0f};"
                 f"infra_visible={int(bool(infra))}")
        rows.append(result_row(f"fig6/nodeinfo/{pname}/vus20", res,
                               DURATION, extra))

        if cold:
            t_half = DURATION / 2
            late = sum(v for t, v in cold if t > t_half)
            early = sum(v for t, v in cold if t <= t_half)
            check(late <= early,
                  f"{pname}: cold starts should concentrate early", failures)
        if pname == "google-cloud-cluster":
            check(not infra, "gcf infra metrics must be unavailable",
                  failures)
        else:
            check(bool(infra), f"{pname} infra metrics must be visible",
                  failures)
        check(len(res.completed) > 0, f"{pname} served nothing", failures)
    return rows, failures


if __name__ == "__main__":
    rows, failures = run_bench()
    for r in rows:
        print(r.csv())
    print("failures:", failures or "none")
