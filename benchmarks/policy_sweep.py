"""Beyond-paper: head-to-head sweep of every FDN scheduling policy on the
same mixed workload (the experiment the paper's outlook §9 calls for, with
the FDN now actually built).

Runs through the FDNInspector scenario runner (``registry.
policy_sweep_cell`` — four closed-loop streams per policy arm, plus the
open-loop Poisson arm through the batched gateway path).  Stream seeds are
derived deterministically by the runner; the old hand-wired sweep seeded
VU pools with salted ``hash(fn)`` and was not replayable across processes.

Claims asserted:
  * the SLO-composite policy meets >=99% of SLOs at LOWER energy than
    round-robin (the FDN trade-off the paper argues for);
  * the energy-aware policy uses less total energy than perf-ranked;
  * perf-ranked has the lowest P90 of the static policies.
"""
from __future__ import annotations

from typing import List, Tuple

from benchmarks.fdn_common import Row, check
from repro.inspector import registry, run_scenario

DURATION = 90.0


def _run(policy: str):
    rep = run_scenario(registry.policy_sweep_cell(policy,
                                                  duration_s=DURATION))
    t = rep.totals
    joules = sum(p["energy_j"] for p in rep.per_platform.values())
    met = t["completed"] - t["slo_violations"] + t["rejected"]
    return {"met": met, "n": t["completed"], "joules": joules,
            "p90": t["p90_s"], "rejected": t["rejected"]}


def run_bench() -> Tuple[List[Row], List[str]]:
    rows: List[Row] = []
    failures: List[str] = []
    stats = {}
    for name in registry.SWEEP_POLICIES:
        s = _run(name)
        stats[name] = s
        rows.append(Row(f"policy_sweep/{name}", s["p90"] * 1e6,
                        f"slo_met={s['met']}/{s['n']};"
                        f"joules={s['joules']:.0f};p90_s={s['p90']:.3f}"))

    comp = stats["slo_composite"]
    check(comp["met"] / comp["n"] >= 0.99,
          "composite should meet >=99% of SLOs", failures)
    check(comp["joules"] < stats["round_robin"]["joules"],
          "composite should use less energy than round-robin at equal "
          "compliance", failures)
    check(stats["energy_aware"]["joules"] <= stats["perf_ranked"]["joules"],
          "energy-aware should burn less than perf-ranked", failures)
    check(stats["perf_ranked"]["p90"] <= stats["round_robin"]["p90"],
          "perf-ranked should have lower P90 than round-robin", failures)

    # open-loop Poisson arrivals through the batched gateway path: the
    # composite policy must hold the SLO under burst admission too
    rep = run_scenario(registry.policy_sweep_open_loop(DURATION))
    t = rep.totals
    nodeinfo = rep.per_function["nodeinfo"]
    rows.append(Row("policy_sweep/slo_composite_open_loop",
                    t["mean_s"] * 1e6,
                    f"p90_s={t['p90_s']:.3f};rps={t['rps']:.1f};"
                    f"n={t['completed']};rejected={t['rejected']}"))
    check(t["rejected"] == 0,
          "open-loop batched path should admit every arrival", failures)
    check(t["completed"] == t["submitted"],
          "open-loop batched path should complete every arrival", failures)
    check(t["p90_s"] <= nodeinfo["slo_s"],
          "open-loop batched path should meet the nodeinfo SLO", failures)
    return rows, failures


if __name__ == "__main__":
    rows, failures = run_bench()
    for r in rows:
        print(r.csv())
    print("failures:", failures or "none")
