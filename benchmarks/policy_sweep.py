"""Beyond-paper: head-to-head sweep of every FDN scheduling policy on the
same mixed workload (the experiment the paper's outlook §9 calls for, with
the FDN now actually built).

Claims asserted:
  * the SLO-composite policy meets >=99% of SLOs at LOWER energy than
    round-robin (the FDN trade-off the paper argues for);
  * the energy-aware policy uses less total energy than perf-ranked;
  * perf-ranked has the lowest P90 of the static policies.
"""
from __future__ import annotations

from typing import List, Tuple

from benchmarks.fdn_common import Row, build_fdn, check
from repro.core import (EnergyAwarePolicy, PerformanceRankedPolicy,
                        RoundRobinCollaboration, SLOCompositePolicy,
                        UtilizationAwarePolicy)
from repro.core.loadgen import (ColumnarResultSink, poisson_arrivals,
                                run_arrivals, run_load)

DURATION = 90.0
OPEN_LOOP_RPS = 60.0


def _run(policy_name: str):
    cp, gw, fns = build_fdn()
    policy = {
        "perf_ranked": lambda: PerformanceRankedPolicy(cp.perf),
        "utilization": lambda: UtilizationAwarePolicy(cp.perf),
        "round_robin": lambda: RoundRobinCollaboration(),
        "energy": lambda: EnergyAwarePolicy(cp.perf),
        "slo_composite": lambda: SLOCompositePolicy(cp.perf, cp.placement),
    }[policy_name]()
    cp.policy = policy
    invs = []
    for fn in ("nodeinfo", "primes-python", "JSON-loads",
               "image-processing"):
        res = run_load(cp.clock, lambda i: gw.request(i), fns[fn], vus=8,
                       duration_s=DURATION, sleep_s=0.1,
                       seed=hash(fn) % 1000)
        invs += res.completed
    met = sum(1 for i in invs
              if i.response_time is not None
              and i.response_time <= i.fn.slo.p90_response_s)
    joules = sum(cp.energy.joules(p) for p in cp.platforms)
    from repro.core.monitoring import percentile
    p90 = percentile(sorted(i.response_time for i in invs), 0.90)
    return {"met": met, "n": len(invs), "joules": joules, "p90": p90}


def run_bench() -> Tuple[List[Row], List[str]]:
    rows: List[Row] = []
    failures: List[str] = []
    stats = {}
    for name in ("perf_ranked", "utilization", "round_robin", "energy",
                 "slo_composite"):
        s = _run(name)
        stats[name] = s
        rows.append(Row(f"policy_sweep/{name}", s["p90"] * 1e6,
                        f"slo_met={s['met']}/{s['n']};"
                        f"joules={s['joules']:.0f};p90_s={s['p90']:.3f}"))

    comp = stats["slo_composite"]
    check(comp["met"] / comp["n"] >= 0.99,
          "composite should meet >=99% of SLOs", failures)
    check(comp["joules"] < stats["round_robin"]["joules"],
          "composite should use less energy than round-robin at equal "
          "compliance", failures)
    check(stats["energy"]["joules"] <= stats["perf_ranked"]["joules"],
          "energy-aware should burn less than perf-ranked", failures)
    check(stats["perf_ranked"]["p90"] <= stats["round_robin"]["p90"],
          "perf-ranked should have lower P90 than round-robin", failures)

    # open-loop Poisson arrivals through the batched gateway path: the
    # composite policy must hold the SLO under burst admission too
    cp, gw, fns = build_fdn()
    sink = ColumnarResultSink().install(cp)
    arrivals = poisson_arrivals(OPEN_LOOP_RPS, DURATION, seed=11)
    run_arrivals(cp.clock, gw.request_batch, fns["nodeinfo"], arrivals,
                 batch_window_s=0.1, sink=sink)
    rows.append(Row("policy_sweep/slo_composite_open_loop",
                    sink.mean_response() * 1e6,
                    f"p90_s={sink.p90_response():.3f};"
                    f"rps={sink.requests_per_s(DURATION):.1f};"
                    f"n={sink.completed};rejected={sink.rejected}"))
    check(sink.rejected == 0,
          "open-loop batched path should admit every arrival", failures)
    check(sink.completed == arrivals.size,
          "open-loop batched path should complete every arrival", failures)
    check(sink.p90_response() <= fns["nodeinfo"].slo.p90_response_s,
          "open-loop batched path should meet the nodeinfo SLO", failures)
    return rows, failures


if __name__ == "__main__":
    rows, failures = run_bench()
    for r in rows:
        print(r.csv())
    print("failures:", failures or "none")
