"""Fig. 11: image-processing at 20 VUs — (1) cloud-cluster with a local
MinIO, (2) cloud-cluster reading the remote us-east MinIO, (3) executing on
google-cloud-cluster next to the remote store, (4) migrating the object to
the compute platform first.

Runs through the FDNInspector scenario runner (``registry.fig11_cell``):
``Scenario.data_location=REMOTE_STORE`` seeds the object at the remote
store only (the exclusivity the hand-wired harness faked by deleting
copies), and ``Scenario.migrate_objects`` expresses the §5.1.4 adaptive
data-management move declaratively.

Paper claims validated here:
  * local data beats remote data on the same platform (more req/s, lower
    P90);
  * gcf-near-data is nevertheless the WORST option for this compute-ish
    function (compute weakness + cross-region request path dominate);
  * migrating the object to the compute platform recovers the local-access
    performance.
"""
from __future__ import annotations

from typing import List, Tuple

from benchmarks.fdn_common import Row, check, scenario_row
from repro.inspector import registry
from repro.inspector.scenario import run_scenario_state

DURATION = 120.0


def _arm(variant: str):
    rep, cp, _sink = run_scenario_state(registry.fig11_cell(variant))
    platform = registry.FIG11_ARMS[variant][0]
    return rep, cp, rep.per_platform[platform]


def run_bench() -> Tuple[List[Row], List[str]]:
    rows: List[Row] = []
    failures: List[str] = []

    _, _, local = _arm("cloud-local-minio")
    rows.append(scenario_row("fig11/cloud_local_minio", local))

    _, _, remote = _arm("cloud-remote-minio")
    rows.append(scenario_row("fig11/cloud_remote_minio", remote))

    _, _, gcf = _arm("gcf-near-data")
    rows.append(scenario_row("fig11/gcf_near_data", gcf))

    _, cp, migrated = _arm("cloud-after-migration")
    rows.append(scenario_row("fig11/cloud_after_migration", migrated,
                             extra=f"migrations={cp.placement.migrations}"))

    check(local["rps"] > remote["rps"],
          "local MinIO should serve more req/s than remote", failures)
    check(local["p90_s"] < remote["p90_s"],
          "local MinIO should have lower P90 than remote", failures)
    check(gcf["p90_s"] > local["p90_s"],
          "gcf-near-data should be worse than cloud-local", failures)
    check(migrated["p90_s"] < remote["p90_s"] * 1.05,
          "migration should recover (near-)local performance", failures)
    check(cp.placement.migrations >= 1, "migration must have happened",
          failures)
    return rows, failures


if __name__ == "__main__":
    rows, failures = run_bench()
    for r in rows:
        print(r.csv())
    print("failures:", failures or "none")
