"""Fig. 11: image-processing at 20 VUs — (1) cloud-cluster with a local
MinIO, (2) cloud-cluster reading the remote us-east MinIO, (3) executing on
google-cloud-cluster next to the remote store.

Paper claims validated here:
  * local data beats remote data on the same platform (more req/s, lower
    P90);
  * gcf-near-data is nevertheless the WORST option for this compute-ish
    function (compute weakness + cross-region request path dominate);
  * migrating the object to the compute platform recovers the local-access
    performance (the FDN's adaptive data-management move, §5.1.4).
"""
from __future__ import annotations

from typing import List, Tuple

from benchmarks.fdn_common import (IMAGE_KEY, REMOTE_STORE, Row, build_fdn,
                                   check, result_row, run_on_platform)

DURATION = 120.0


def _run(data_location: str, platform: str, migrate_first: bool = False):
    cp, gw, fns = build_fdn(data_location=data_location)
    if data_location != platform and data_location == REMOTE_STORE:
        # ensure ONLY the remote copy exists for the remote scenarios
        for name, store in cp.placement.stores.items():
            if name != REMOTE_STORE and store.has(IMAGE_KEY):
                del store.objects[IMAGE_KEY]
    if migrate_first:
        cp.placement.migrate(IMAGE_KEY, platform)
    res = run_on_platform(cp, gw, fns["image-processing"], platform, 20,
                          DURATION, sleep_s=0.2)
    return cp, res


def run_bench() -> Tuple[List[Row], List[str]]:
    rows: List[Row] = []
    failures: List[str] = []

    _, local = _run("cloud-cluster", "cloud-cluster")
    rows.append(result_row("fig11/cloud_local_minio", local, DURATION))

    _, remote = _run(REMOTE_STORE, "cloud-cluster")
    rows.append(result_row("fig11/cloud_remote_minio", remote, DURATION))

    _, gcf = _run(REMOTE_STORE, "google-cloud-cluster")
    rows.append(result_row("fig11/gcf_near_data", gcf, DURATION))

    cp, migrated = _run(REMOTE_STORE, "cloud-cluster", migrate_first=True)
    rows.append(result_row(
        "fig11/cloud_after_migration", migrated, DURATION,
        extra=f"migrations={cp.placement.migrations}"))

    check(local.requests_per_s(DURATION) > remote.requests_per_s(DURATION),
          "local MinIO should serve more req/s than remote", failures)
    check(local.p90_response() < remote.p90_response(),
          "local MinIO should have lower P90 than remote", failures)
    check(gcf.p90_response() > local.p90_response(),
          "gcf-near-data should be worse than cloud-local", failures)
    check(migrated.p90_response() < remote.p90_response() * 1.05,
          "migration should recover (near-)local performance", failures)
    check(cp.placement.migrations >= 1, "migration must have happened",
          failures)
    return rows, failures


if __name__ == "__main__":
    rows, failures = run_bench()
    for r in rows:
        print(r.csv())
    print("failures:", failures or "none")
