#!/usr/bin/env bash
# Tier-1 verification: the full pytest suite plus a smoke pass of the
# scheduler-throughput benchmark (catches batched-path regressions that
# unit tests alone would miss).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q

PYTHONPATH="src:." python benchmarks/bench_sched_throughput.py --smoke
